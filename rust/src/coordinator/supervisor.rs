//! Shard supervision: restart policy, per-shard health, pool health.
//!
//! The supervisor is *restart-in-place*: each shard's worker thread is its
//! own supervisor loop (`coordinator/server.rs::supervise`).  A replica
//! panic is caught around `Backend::infer_batch`, every request in the
//! failed batch gets a typed error reply, and the worker rebuilds the
//! replica from the [`BackendFactory`](crate::coordinator::BackendFactory)
//! after an exponential-backoff-with-jitter delay.  `K` *consecutive*
//! crashes (successful batches reset the count) trip a circuit breaker:
//! the shard drains-and-fails its queue, marks itself [`ShardState::Broken`]
//! and exits — dispatch then skips it, and when every shard is broken the
//! pool reports unserviceable so the serving router fails over to a
//! healthy model version.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::time::Duration;

use crate::util::prng::SplitMix64;

/// Restart/backoff/circuit-breaker knobs for a shard supervisor.
#[derive(Debug, Clone, Copy)]
pub struct RestartPolicy {
    /// Trip the circuit breaker after this many *consecutive* crashes
    /// (a successful batch resets the count).  >= 1.
    pub max_consecutive: u32,
    /// Backoff before the first rebuild; doubles per consecutive crash.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        Self {
            max_consecutive: 5,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(200),
        }
    }
}

impl RestartPolicy {
    /// Exponential backoff with deterministic jitter: attempt `n` (1-based)
    /// waits `base * 2^(n-1)`, capped at `max_backoff`, plus up to 25%
    /// seeded jitter so a pool of shards crashing together doesn't rebuild
    /// in lockstep.
    pub fn backoff_delay(&self, attempt: u32, seed: u64) -> Duration {
        let exp = attempt.saturating_sub(1).min(16);
        let base = self
            .base_backoff
            .saturating_mul(1u32 << exp)
            .min(self.max_backoff);
        let mut r = SplitMix64::new(seed ^ u64::from(attempt).wrapping_mul(0x9E37_79B9));
        let jitter_us = (base.as_micros() as u64 / 4).max(1);
        base + Duration::from_micros(r.next_u64() % jitter_us)
    }
}

/// Lifecycle of one shard, as dispatch sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardState {
    /// Serving normally.
    Ready,
    /// Crashed; the supervisor is backing off / rebuilding the replica.
    /// The queue stays open — queued work is served once the rebuild lands.
    Restarting,
    /// Circuit breaker tripped: the worker exited, the queue is drained
    /// and closed.  Terminal until the pool is redeployed.
    Broken,
    /// Graceful shutdown completed.
    Stopped,
}

impl ShardState {
    pub fn label(self) -> &'static str {
        match self {
            ShardState::Ready => "ready",
            ShardState::Restarting => "restarting",
            ShardState::Broken => "broken",
            ShardState::Stopped => "stopped",
        }
    }

    /// Can new work be queued onto this shard?
    pub fn accepts_work(self) -> bool {
        matches!(self, ShardState::Ready | ShardState::Restarting)
    }
}

const STATE_READY: u8 = 0;
const STATE_RESTARTING: u8 = 1;
const STATE_BROKEN: u8 = 2;
const STATE_STOPPED: u8 = 3;

/// Lock-free per-shard health record, shared between the worker thread
/// (writer) and dispatch / health probes (readers).
#[derive(Debug, Default)]
pub struct ShardHealth {
    state: AtomicU8,
    crashes: AtomicU64,
    restarts: AtomicU64,
    consecutive: AtomicU32,
}

impl ShardHealth {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn state(&self) -> ShardState {
        match self.state.load(Ordering::Acquire) {
            STATE_RESTARTING => ShardState::Restarting,
            STATE_BROKEN => ShardState::Broken,
            STATE_STOPPED => ShardState::Stopped,
            _ => ShardState::Ready,
        }
    }

    pub fn set_state(&self, s: ShardState) {
        let v = match s {
            ShardState::Ready => STATE_READY,
            ShardState::Restarting => STATE_RESTARTING,
            ShardState::Broken => STATE_BROKEN,
            ShardState::Stopped => STATE_STOPPED,
        };
        self.state.store(v, Ordering::Release);
    }

    /// Record a crash; returns the new consecutive-crash count.
    pub fn note_crash(&self) -> u32 {
        self.crashes.fetch_add(1, Ordering::Relaxed);
        self.consecutive.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record a successful replica rebuild.
    pub fn note_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch served successfully: the breaker window resets.
    pub fn note_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
    }

    pub fn crashes(&self) -> u64 {
        self.crashes.load(Ordering::Relaxed)
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> ShardHealthSnapshot {
        ShardHealthSnapshot {
            state: self.state(),
            crashes: self.crashes(),
            restarts: self.restarts(),
        }
    }
}

/// Point-in-time view of one shard's health.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardHealthSnapshot {
    pub state: ShardState,
    pub crashes: u64,
    pub restarts: u64,
}

/// Aggregate health of a coordinator pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolHealth {
    pub shards: Vec<ShardHealthSnapshot>,
}

impl PoolHealth {
    /// At least one shard can accept work.
    pub fn serviceable(&self) -> bool {
        self.shards.iter().any(|s| s.state.accepts_work())
    }

    /// Some shard is not `Ready` (load balancers should prefer elsewhere).
    pub fn degraded(&self) -> bool {
        self.shards.iter().any(|s| s.state != ShardState::Ready)
    }

    pub fn crashes(&self) -> u64 {
        self.shards.iter().map(|s| s.crashes).sum()
    }

    pub fn restarts(&self) -> u64 {
        self.shards.iter().map(|s| s.restarts).sum()
    }

    /// `ready` / `degraded` / `down` — the coarse state OP_HEALTH reports.
    pub fn label(&self) -> &'static str {
        if !self.serviceable() {
            "down"
        } else if self.degraded() {
            "degraded"
        } else {
            "ready"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_and_caps() {
        let p = RestartPolicy {
            max_consecutive: 5,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(40),
        };
        let d1 = p.backoff_delay(1, 0);
        let d3 = p.backoff_delay(3, 0);
        let d10 = p.backoff_delay(10, 0);
        // base * 2^(n-1), within the +25% jitter envelope
        assert!(d1 >= Duration::from_millis(5) && d1 < Duration::from_micros(6_250));
        assert!(d3 >= Duration::from_millis(20) && d3 < Duration::from_millis(25));
        // capped at max + jitter
        assert!(d10 >= Duration::from_millis(40) && d10 < Duration::from_millis(50));
        // deterministic for a fixed (attempt, seed)
        assert_eq!(p.backoff_delay(2, 9), p.backoff_delay(2, 9));
    }

    #[test]
    fn breaker_window_resets_on_success() {
        let h = ShardHealth::new();
        assert_eq!(h.note_crash(), 1);
        assert_eq!(h.note_crash(), 2);
        h.note_success();
        assert_eq!(h.note_crash(), 1);
        assert_eq!(h.crashes(), 3);
    }

    #[test]
    fn pool_health_labels() {
        let ready = ShardHealthSnapshot { state: ShardState::Ready, crashes: 0, restarts: 0 };
        let broken = ShardHealthSnapshot { state: ShardState::Broken, crashes: 5, restarts: 4 };
        let restarting =
            ShardHealthSnapshot { state: ShardState::Restarting, crashes: 1, restarts: 0 };
        assert_eq!(PoolHealth { shards: vec![ready, ready] }.label(), "ready");
        assert_eq!(PoolHealth { shards: vec![ready, broken] }.label(), "degraded");
        assert_eq!(PoolHealth { shards: vec![restarting] }.label(), "degraded");
        assert_eq!(PoolHealth { shards: vec![broken, broken] }.label(), "down");
        assert!(PoolHealth { shards: vec![restarting] }.serviceable());
    }
}
