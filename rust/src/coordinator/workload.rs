//! Workload generation for serving experiments.
//!
//! * random 6-bit images (deterministic per seed);
//! * open-loop Poisson arrivals — the "online individual requests" regime
//!   of §6.3 (Baidu's reported batch-8..16 workload);
//! * closed-loop back-to-back submission — the "static data, large batch"
//!   regime.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::server::Client;
use crate::coordinator::InferReply;
use crate::model::NetConfig;
use crate::util::SplitMix64;

/// Deterministic random image in the 6-bit input range.
pub fn random_image(config: &NetConfig, rng: &mut SplitMix64) -> Vec<i32> {
    let n = config.input_hw * config.input_hw * config.input_channels;
    (0..n).map(|_| rng.range_i64(-31, 31) as i32).collect()
}

/// A batch of deterministic random images.
pub fn random_images(config: &NetConfig, count: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = SplitMix64::new(seed);
    (0..count).map(|_| random_image(config, &mut rng)).collect()
}

/// Result of a driven workload.
#[derive(Debug)]
pub struct WorkloadReport {
    pub replies: Vec<InferReply>,
    pub wall: Duration,
}

impl WorkloadReport {
    /// Replies that carried a typed backend error.
    pub fn errors(&self) -> usize {
        self.replies.iter().filter(|r| r.scores.is_err()).count()
    }

    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.replies.len() as f64 / self.wall.as_secs_f64()
    }

    pub fn mean_latency(&self) -> Duration {
        if self.replies.is_empty() {
            return Duration::ZERO;
        }
        let sum: Duration = self.replies.iter().map(|r| r.latency()).sum();
        sum / self.replies.len() as u32
    }

    pub fn mean_batch(&self) -> f64 {
        if self.replies.is_empty() {
            return 0.0;
        }
        self.replies.iter().map(|r| r.batch_size as f64).sum::<f64>() / self.replies.len() as f64
    }
}

/// Open-loop: submit `count` requests with Poisson inter-arrivals at
/// `rate_rps`, then wait for all replies.  Backpressure from the bounded
/// shard queues is waited out (the arrival process stalls — open loop
/// degrades to closed loop at saturation, which is the honest behavior).
pub fn run_open_loop(
    client: &Client,
    config: &NetConfig,
    count: usize,
    rate_rps: f64,
    seed: u64,
) -> Result<WorkloadReport> {
    let mut rng = SplitMix64::new(seed);
    let start = Instant::now();
    let mut pending = Vec::with_capacity(count);
    let mut next_at = Instant::now();
    for _ in 0..count {
        let now = Instant::now();
        if next_at > now {
            std::thread::sleep(next_at - now);
        }
        pending.push(client.submit_blocking(random_image(config, &mut rng))?);
        next_at += Duration::from_secs_f64(rng.exp(rate_rps));
    }
    let mut replies = Vec::with_capacity(count);
    for rx in pending {
        replies.push(rx.recv().map_err(|_| anyhow::anyhow!("coordinator died"))?);
    }
    Ok(WorkloadReport { replies, wall: start.elapsed() })
}

/// Closed-loop: submit everything as fast as the bounded queues admit it
/// (static-data regime), wait all.
pub fn run_closed_loop(
    client: &Client,
    config: &NetConfig,
    count: usize,
    seed: u64,
) -> Result<WorkloadReport> {
    let start = Instant::now();
    let mut rng = SplitMix64::new(seed);
    let pending = (0..count)
        .map(|_| client.submit_blocking(random_image(config, &mut rng)))
        .collect::<std::result::Result<Vec<_>, _>>()?;
    let mut replies = Vec::with_capacity(count);
    for rx in pending {
        replies.push(rx.recv().map_err(|_| anyhow::anyhow!("coordinator died"))?);
    }
    Ok(WorkloadReport { replies, wall: start.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_images_deterministic() {
        let cfg = NetConfig::tiny();
        let a = random_images(&cfg, 3, 7);
        let b = random_images(&cfg, 3, 7);
        assert_eq!(a, b);
        assert_ne!(a, random_images(&cfg, 3, 8));
        assert!(a[0].iter().all(|&v| (-31..=31).contains(&v)));
        assert_eq!(a[0].len(), 16 * 16 * 3);
    }
}
