//! Workload generation for serving experiments.
//!
//! * random 6-bit images (deterministic per seed);
//! * open-loop Poisson arrivals — the "online individual requests" regime
//!   of §6.3 (Baidu's reported batch-8..16 workload);
//! * closed-loop back-to-back submission — the "static data, large batch"
//!   regime;
//! * a multiplexed TCP front-end load driver ([`run_frontend_load`]) —
//!   hundreds-to-thousands of pipelined nonblocking connections from a
//!   handful of client threads, speaking v1 or v2-QoS wire frames, for
//!   benchmarking the server front-ends at connection counts a
//!   thread-per-connection *client* could not reach honestly.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::qos::Lane;
use crate::coordinator::server::{Client, MAX_WIRE_VALUES, WIRE_ERROR};
use crate::coordinator::InferReply;
use crate::model::NetConfig;
use crate::util::SplitMix64;

/// Deterministic random image in the 6-bit input range.
pub fn random_image(config: &NetConfig, rng: &mut SplitMix64) -> Vec<i32> {
    let n = config.input_hw * config.input_hw * config.input_channels;
    (0..n).map(|_| rng.range_i64(-31, 31) as i32).collect()
}

/// A batch of deterministic random images.
pub fn random_images(config: &NetConfig, count: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = SplitMix64::new(seed);
    (0..count).map(|_| random_image(config, &mut rng)).collect()
}

/// Result of a driven workload.
#[derive(Debug)]
pub struct WorkloadReport {
    pub replies: Vec<InferReply>,
    pub wall: Duration,
}

impl WorkloadReport {
    /// Replies that carried a typed backend error.
    pub fn errors(&self) -> usize {
        self.replies.iter().filter(|r| r.scores.is_err()).count()
    }

    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.replies.len() as f64 / self.wall.as_secs_f64()
    }

    pub fn mean_latency(&self) -> Duration {
        if self.replies.is_empty() {
            return Duration::ZERO;
        }
        let sum: Duration = self.replies.iter().map(|r| r.latency()).sum();
        sum / self.replies.len() as u32
    }

    pub fn mean_batch(&self) -> f64 {
        if self.replies.is_empty() {
            return 0.0;
        }
        self.replies.iter().map(|r| r.batch_size as f64).sum::<f64>() / self.replies.len() as f64
    }
}

/// Open-loop: submit `count` requests with Poisson inter-arrivals at
/// `rate_rps`, then wait for all replies.  Backpressure from the bounded
/// shard queues is waited out (the arrival process stalls — open loop
/// degrades to closed loop at saturation, which is the honest behavior).
pub fn run_open_loop(
    client: &Client,
    config: &NetConfig,
    count: usize,
    rate_rps: f64,
    seed: u64,
) -> Result<WorkloadReport> {
    let mut rng = SplitMix64::new(seed);
    let start = Instant::now();
    let mut pending = Vec::with_capacity(count);
    let mut next_at = Instant::now();
    for _ in 0..count {
        let now = Instant::now();
        if next_at > now {
            std::thread::sleep(next_at - now);
        }
        pending.push(client.submit_blocking(random_image(config, &mut rng))?);
        next_at += Duration::from_secs_f64(rng.exp(rate_rps));
    }
    let mut replies = Vec::with_capacity(count);
    for rx in pending {
        replies.push(rx.recv().map_err(|_| anyhow::anyhow!("coordinator died"))?);
    }
    Ok(WorkloadReport { replies, wall: start.elapsed() })
}

/// Closed-loop: submit everything as fast as the bounded queues admit it
/// (static-data regime), wait all.
pub fn run_closed_loop(
    client: &Client,
    config: &NetConfig,
    count: usize,
    seed: u64,
) -> Result<WorkloadReport> {
    let start = Instant::now();
    let mut rng = SplitMix64::new(seed);
    let pending = (0..count)
        .map(|_| client.submit_blocking(random_image(config, &mut rng)))
        .collect::<std::result::Result<Vec<_>, _>>()?;
    let mut replies = Vec::with_capacity(count);
    for rx in pending {
        replies.push(rx.recv().map_err(|_| anyhow::anyhow!("coordinator died"))?);
    }
    Ok(WorkloadReport { replies, wall: start.elapsed() })
}

// ---------------------------------------------------------------------------
// multiplexed TCP front-end load driver
// ---------------------------------------------------------------------------

/// Which wire dialect the load driver speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadProto {
    /// v1 frames on the default model (length-tagged).
    V1,
    /// v2 `OP_INFER_QOS` frames: lane-tagged, deadline-bounded
    /// (`deadline_ms` 0 = the server's default for the lane).
    Qos { lane: Lane, deadline_ms: u32 },
}

/// Configuration for [`run_frontend_load`].
#[derive(Debug, Clone)]
pub struct FrontendLoadConfig {
    pub addr: SocketAddr,
    /// Concurrent TCP connections (split evenly across `threads`).
    pub connections: usize,
    /// Client threads, each multiplexing its share of nonblocking
    /// connections (poll-style, no thread per connection).
    pub threads: usize,
    /// Max pipelined in-flight requests per connection.
    pub window: usize,
    /// How long to keep issuing new requests (then drain).
    pub duration: Duration,
    /// Total open-loop Poisson arrival rate across all connections;
    /// `None` saturates every connection's window instead.
    pub rate_rps: Option<f64>,
    pub proto: LoadProto,
    pub seed: u64,
}

/// Aggregated result of a front-end load run.  Conservation invariant:
/// every request written to a socket is accounted exactly once —
/// `sent == ok + errors + expired + lost`, and `lost` stays 0 unless the
/// server dropped a connection or the drain timed out.
#[derive(Debug, Default)]
pub struct FrontendLoadReport {
    pub sent: u64,
    /// Scores replies.
    pub ok: u64,
    /// Typed error frames (overload, backend failure, injected faults).
    pub errors: u64,
    /// Typed `REPLY_EXPIRED` frames (deadline sheds).
    pub expired: u64,
    /// Requests written but never answered (dead connection or drain
    /// timeout) — nonzero means the server silently dropped work.
    pub lost: u64,
    pub wall: Duration,
    /// Reply latencies in microseconds (enqueue to decoded reply),
    /// unsorted.
    pub latencies_us: Vec<u64>,
}

impl FrontendLoadReport {
    pub fn merge(&mut self, other: FrontendLoadReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.errors += other.errors;
        self.expired += other.expired;
        self.lost += other.lost;
        self.wall = self.wall.max(other.wall);
        self.latencies_us.extend(other.latencies_us);
    }

    /// Answered requests per second of wall time.
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        (self.ok + self.errors + self.expired) as f64 / self.wall.as_secs_f64()
    }

    /// Latency percentile (`p` in 0..=100) in milliseconds.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        let mut sorted = self.latencies_us.clone();
        sorted.sort_unstable();
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)] as f64 / 1000.0
    }

    /// Every sent request got exactly one reply.
    pub fn conservation_ok(&self) -> bool {
        self.lost == 0 && self.sent == self.ok + self.errors + self.expired
    }
}

/// How long the driver waits for in-flight replies after the issue
/// window closes before declaring them lost.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(10);
/// Idle sleep when no connection made progress (keeps the poll loop from
/// spinning a core per client thread).
const LOAD_IDLE_SLEEP: Duration = Duration::from_micros(200);

/// One reply decoded off a connection's read buffer.
enum ReplyKind {
    Ok,
    Error,
    Expired,
}

/// Incrementally decode one server reply (v1 or v2).  `None` = the
/// buffer does not yet hold a complete frame; `Err` = the stream is not
/// a recognizable reply (protocol violation — the connection is dead).
fn parse_reply(buf: &[u8]) -> Result<Option<(ReplyKind, usize)>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let tag = u32::from_le_bytes(buf[..4].try_into().unwrap());
    // message-bearing frames: error (v1+v2) and typed expiry (v2)
    if tag == WIRE_ERROR || tag == crate::serving::admin::REPLY_EXPIRED {
        if buf.len() < 8 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        if buf.len() < 8 + len {
            return Ok(None);
        }
        let kind = if tag == WIRE_ERROR { ReplyKind::Error } else { ReplyKind::Expired };
        return Ok(Some((kind, 8 + len)));
    }
    if tag == crate::serving::admin::REPLY_SCORES {
        // version + trace_id + count, then the scores
        if buf.len() < 24 {
            return Ok(None);
        }
        let n = u32::from_le_bytes(buf[20..24].try_into().unwrap()) as usize;
        if n > MAX_WIRE_VALUES {
            anyhow::bail!("implausible v2 score count {n}");
        }
        if buf.len() < 24 + n * 4 {
            return Ok(None);
        }
        return Ok(Some((ReplyKind::Ok, 24 + n * 4)));
    }
    // v1 scores reply: the tag is the score count
    let n = tag as usize;
    if n > MAX_WIRE_VALUES {
        anyhow::bail!("unrecognized reply tag {tag:#010x}");
    }
    if buf.len() < 4 + n * 4 {
        return Ok(None);
    }
    Ok(Some((ReplyKind::Ok, 4 + n * 4)))
}

/// Encode one request frame for `proto`.
fn request_frame(proto: LoadProto, image: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(18 + image.len() * 4);
    match proto {
        LoadProto::V1 => {
            out.extend_from_slice(&(image.len() as u32).to_le_bytes());
        }
        LoadProto::Qos { lane, deadline_ms } => {
            out.extend_from_slice(&crate::serving::admin::OP_INFER_QOS.to_le_bytes());
            out.extend_from_slice(&0u16.to_le_bytes()); // default model
            out.extend_from_slice(&lane.wire().to_le_bytes());
            out.extend_from_slice(&deadline_ms.to_le_bytes());
            out.extend_from_slice(&(image.len() as u32).to_le_bytes());
        }
    }
    for v in image {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// One multiplexed client connection: nonblocking socket, partial-write
/// outbox, incremental read buffer, FIFO of in-flight send timestamps
/// (pipelined replies come back in order, so front-of-queue matches the
/// next decoded reply).
struct LoadConn {
    stream: TcpStream,
    out: Vec<u8>,
    opos: usize,
    rbuf: Vec<u8>,
    inflight: VecDeque<Instant>,
    dead: bool,
}

impl LoadConn {
    fn connect(addr: SocketAddr) -> Result<LoadConn> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).context("set_nonblocking")?;
        Ok(LoadConn {
            stream,
            out: Vec::new(),
            opos: 0,
            rbuf: Vec::new(),
            inflight: VecDeque::new(),
            dead: false,
        })
    }

    fn enqueue(&mut self, frame: &[u8]) {
        self.out.extend_from_slice(frame);
        self.inflight.push_back(Instant::now());
    }

    /// Flush the outbox and drain readable replies into `report`.
    /// Returns true if any bytes moved.
    fn pump(&mut self, report: &mut FrontendLoadReport) -> bool {
        if self.dead {
            return false;
        }
        let mut progressed = false;
        while self.opos < self.out.len() {
            match self.stream.write(&self.out[self.opos..]) {
                Ok(0) => {
                    self.dead = true;
                    return true;
                }
                Ok(n) => {
                    self.opos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return true;
                }
            }
        }
        if self.opos == self.out.len() && !self.out.is_empty() {
            self.out.clear();
            self.opos = 0;
        }
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        let mut pos = 0;
        loop {
            match parse_reply(&self.rbuf[pos..]) {
                Ok(Some((kind, used))) => {
                    pos += used;
                    if let Some(sent_at) = self.inflight.pop_front() {
                        report.latencies_us.push(sent_at.elapsed().as_micros() as u64);
                    }
                    match kind {
                        ReplyKind::Ok => report.ok += 1,
                        ReplyKind::Error => report.errors += 1,
                        ReplyKind::Expired => report.expired += 1,
                    }
                }
                Ok(None) => break,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if pos > 0 {
            self.rbuf.drain(..pos);
        }
        progressed
    }
}

/// Drive the TCP front-end at `cfg.addr` with multiplexed pipelined
/// connections and report per-request outcomes.  Every request is
/// accounted exactly once (see [`FrontendLoadReport`]); unanswered
/// requests surface as `lost` rather than vanishing, so a benchmark
/// built on this driver can assert the server sheds *typed* replies
/// instead of silently dropping work.
pub fn run_frontend_load(cfg: &FrontendLoadConfig, image: &[i32]) -> Result<FrontendLoadReport> {
    anyhow::ensure!(cfg.connections > 0, "need at least one connection");
    anyhow::ensure!(cfg.window > 0, "need a nonzero pipeline window");
    let threads = cfg.threads.clamp(1, cfg.connections);
    let frame = request_frame(cfg.proto, image);
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        // spread the remainder so connection counts differ by at most 1
        let share = cfg.connections / threads + usize::from(t < cfg.connections % threads);
        let cfg = cfg.clone();
        let frame = frame.clone();
        handles.push(std::thread::spawn(move || drive_share(&cfg, t, share, &frame)));
    }
    let mut report = FrontendLoadReport::default();
    for h in handles {
        match h.join() {
            Ok(Ok(part)) => report.merge(part),
            Ok(Err(e)) => return Err(e),
            Err(p) => anyhow::bail!("load thread panicked: {}", crate::util::sync::panic_message(&*p)),
        }
    }
    Ok(report)
}

/// One client thread's share of the load: `share` connections, windowed
/// pipelining, optional Poisson pacing, then drain.
fn drive_share(
    cfg: &FrontendLoadConfig,
    thread_idx: usize,
    share: usize,
    frame: &[u8],
) -> Result<FrontendLoadReport> {
    let mut report = FrontendLoadReport::default();
    if share == 0 {
        return Ok(report);
    }
    let mut conns = Vec::with_capacity(share);
    for _ in 0..share {
        conns.push(LoadConn::connect(cfg.addr)?);
    }
    let mut rng = SplitMix64::new(cfg.seed ^ (thread_idx as u64).wrapping_mul(0x9E37_79B9));
    let per_thread_rate = cfg.rate_rps.map(|r| (r / cfg.threads.max(1) as f64).max(0.001));
    let start = Instant::now();
    let issue_until = start + cfg.duration;
    let mut next_at = start;
    let mut rr = 0usize;
    loop {
        let now = Instant::now();
        let issuing = now < issue_until;
        if issuing {
            match per_thread_rate {
                None => {
                    for conn in conns.iter_mut().filter(|c| !c.dead) {
                        while conn.inflight.len() < cfg.window {
                            conn.enqueue(frame);
                            report.sent += 1;
                        }
                    }
                }
                Some(rate) => {
                    while next_at <= now {
                        // round-robin over live connections with window room
                        let pick = (0..conns.len())
                            .map(|i| (rr + i) % conns.len())
                            .find(|&i| !conns[i].dead && conns[i].inflight.len() < cfg.window);
                        match pick {
                            Some(i) => {
                                conns[i].enqueue(frame);
                                report.sent += 1;
                                rr = i + 1;
                            }
                            None => break, // every window full: arrivals stall
                        }
                        next_at += Duration::from_secs_f64(rng.exp(rate));
                    }
                }
            }
        }
        let mut progressed = false;
        for conn in conns.iter_mut() {
            progressed |= conn.pump(&mut report);
        }
        let inflight: usize = conns.iter().map(|c| c.inflight.len()).sum();
        if !issuing {
            // dead connections will never answer; count their in-flight
            // requests as lost and stop waiting on them
            if conns.iter().all(|c| c.dead || c.inflight.is_empty()) {
                break;
            }
            if now > issue_until + DRAIN_TIMEOUT {
                break;
            }
        }
        if inflight == 0 && !issuing {
            break;
        }
        if !progressed {
            std::thread::sleep(LOAD_IDLE_SLEEP);
        }
    }
    for conn in &conns {
        report.lost += conn.inflight.len() as u64;
    }
    report.wall = start.elapsed();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_images_deterministic() {
        let cfg = NetConfig::tiny();
        let a = random_images(&cfg, 3, 7);
        let b = random_images(&cfg, 3, 7);
        assert_eq!(a, b);
        assert_ne!(a, random_images(&cfg, 3, 8));
        assert!(a[0].iter().all(|&v| (-31..=31).contains(&v)));
        assert_eq!(a[0].len(), 16 * 16 * 3);
    }
}
