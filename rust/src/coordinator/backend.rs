//! Pluggable inference backends.
//!
//! * [`NativeBackend`] — the packed-u64 engine: the production hot path,
//!   optionally fanning a batch across intra-batch lanes (scoped threads,
//!   one `Scratch` per lane).
//! * [`PjrtBackend`] — the AOT HLO executable via PJRT (proves the
//!   three-layer compose; numerics must match the native engine).
//! * [`FpgaSimBackend`] — native numerics + the FPGA timing model: replies
//!   carry the *modeled* device time, so serving experiments report what
//!   the paper's accelerator would deliver.
//! * [`GpuSimBackend`] — native numerics + the Titan X analytic model
//!   (whole-batch completion), the Fig. 7 comparator on the serving path.
//! * [`crate::pipeline::PipelineBackend`] — the row-streaming
//!   layer-pipeline runtime (all layers concurrently active, paper §4);
//!   lives in `crate::pipeline` and is re-exported from
//!   [`crate::coordinator`].

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::bcnn::engine::Scratch;
use crate::bcnn::Engine;
use crate::fpga::stream::{simulate, StreamConfig};
use crate::fpga::timing::{paper_fc_params, paper_table3_conv_params, LayerParams, PipelineModel};
use crate::fpga::{layer_geometry, DEFAULT_FREQ_HZ};
use crate::gpu::{GpuKernel, GpuModel};
use crate::model::BcnnModel;
use crate::optimizer::{optimize, OptimizeOptions};
use crate::runtime::LoadedModel;

/// Result of one batch execution.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub scores: Vec<Vec<f32>>,
    /// Modeled device time (simulator backends); `None` = wall clock only.
    pub modeled_device_time: Option<Duration>,
}

/// An inference backend consuming whole batches.
///
/// Batches arrive as *borrowed* image views (`&[&[i32]]`): the coordinator
/// worker lends each queued request's buffer directly, so batch formation
/// never copies pixel data.
///
/// Deliberately NOT `Send`: PJRT client/executable handles are `Rc`-based.
/// The coordinator therefore constructs one backend replica *on* each
/// worker thread via a [`BackendFactory`].
pub trait Backend {
    fn name(&self) -> &str;
    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchResult>;

    /// Convenience for owned batches (tests/CLI); borrows and delegates.
    fn infer_owned(&mut self, images: &[Vec<i32>]) -> Result<BatchResult> {
        let views: Vec<&[i32]> = images.iter().map(|v| v.as_slice()).collect();
        self.infer_batch(&views)
    }

    /// `infer_batch` with the requests' trace IDs (one per image, same
    /// order) so backends with internal concurrency can label their own
    /// spans — the pipeline backend threads these through its stages.
    /// Backends without internal spans just delegate.
    fn infer_batch_traced(&mut self, images: &[&[i32]], trace_ids: &[u64]) -> Result<BatchResult> {
        let _ = trace_ids;
        self.infer_batch(images)
    }

    /// Per-stage busy/stall observability for pipeline-backed replicas
    /// (cumulative since construction); empty for backends that have no
    /// stages.  The shard worker folds this into its [`Metrics`] snapshot
    /// so `STATS`/bench JSON show *which* stage bottlenecks.
    ///
    /// [`Metrics`]: crate::coordinator::Metrics
    fn stage_stats(&self) -> Vec<crate::pipeline::stage::StageSnapshot> {
        Vec::new()
    }

    /// Name of the bitwise SIMD kernel the replica's engine dispatches to
    /// (`"scalar"`/`"avx2"`/`"avx512"`); empty for backends without a
    /// host engine hot path.  Folded into the shard [`Metrics`] so
    /// `STATS`/bench JSON record which datapath produced the numbers.
    ///
    /// [`Metrics`]: crate::coordinator::Metrics
    fn kernel(&self) -> &'static str {
        ""
    }

    /// Cumulative requests this replica served via an internal degradation
    /// path (e.g. the pipeline backend re-running a batch on the bit-exact
    /// engine after a stage death).  The shard worker folds the delta into
    /// `Metrics::requests_failed_over`.
    fn failovers(&self) -> u64 {
        0
    }

    /// Cumulative internal thread crashes this replica contained (e.g.
    /// pipeline stage-lane panics).  Folded into `Metrics::crashes` by the
    /// shard worker.
    fn crashes(&self) -> u64 {
        0
    }
}

/// Per-worker backend factory: the sharded coordinator calls it once on
/// every worker thread to build that shard's replica.  `Fn` (not `FnOnce`)
/// because a pool of N workers needs N replicas.
pub type BackendFactory = Arc<dyn Fn() -> Result<Box<dyn Backend>> + Send + Sync>;

// ---------------------------------------------------------------------------

/// The native packed-u64 engine, with optional intra-batch parallelism:
/// `lanes > 1` splits each batch across scoped threads sharing the same
/// `Engine` (it is `Sync`; weights are read-only), one `Scratch` per lane.
pub struct NativeBackend {
    engine: Engine,
    scratches: Vec<Scratch>,
}

impl NativeBackend {
    pub fn new(model: BcnnModel) -> Result<Self> {
        Self::with_lanes(model, 1)
    }

    /// `lanes` intra-batch worker threads (clamped to at least 1).  One
    /// [`Scratch`] arena per lane: the tap-major engine is zero-alloc per
    /// image once each lane's arena is warm.
    pub fn with_lanes(model: BcnnModel, lanes: usize) -> Result<Self> {
        Ok(Self { engine: Engine::new(model)?, scratches: vec![Scratch::default(); lanes.max(1)] })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn lanes(&self) -> usize {
        self.scratches.len()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchResult> {
        let lanes = self.scratches.len();
        let scores = if lanes == 1 || images.len() < 2 {
            let scratch = &mut self.scratches[0];
            images
                .iter()
                .map(|img| self.engine.infer_with_scratch(img, scratch))
                .collect::<Result<Vec<_>>>()?
        } else {
            // Split the batch into one contiguous chunk per lane; scoped
            // threads share `&Engine` and own one `&mut Scratch` each, so
            // the hot path stays allocation-reusing per lane.
            let chunk = images.len().div_ceil(lanes);
            let engine = &self.engine;
            let lane_results: Vec<Result<Vec<Vec<f32>>>> = std::thread::scope(|s| {
                let handles: Vec<_> = images
                    .chunks(chunk)
                    .zip(self.scratches.iter_mut())
                    .map(|(part, scratch)| {
                        s.spawn(move || {
                            part.iter()
                                .map(|img| engine.infer_with_scratch(img, scratch))
                                .collect::<Result<Vec<_>>>()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h.join() {
                        Ok(r) => r,
                        Err(_) => Err(anyhow!("inference lane panicked")),
                    })
                    .collect()
            });
            let mut scores = Vec::with_capacity(images.len());
            for lane in lane_results {
                scores.extend(lane?);
            }
            scores
        };
        Ok(BatchResult { scores, modeled_device_time: None })
    }

    fn kernel(&self) -> &'static str {
        self.engine.kernel().name()
    }
}

// ---------------------------------------------------------------------------

/// PJRT executable backend (fixed lowered batch size; shorter batches are
/// padded, longer ones chunked).
pub struct PjrtBackend {
    model: LoadedModel,
    name: String,
}

impl PjrtBackend {
    pub fn new(model: LoadedModel) -> Self {
        let name = format!("pjrt-b{}", model.batch());
        Self { model, name }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchResult> {
        let lot = self.model.batch();
        let classes = self.model.classes();
        let per_image: usize = self.model.manifest.input_shape.iter().skip(1).product();
        let mut scores = Vec::with_capacity(images.len());
        for chunk in images.chunks(lot) {
            let mut flat = vec![0i32; lot * per_image];
            for (i, img) in chunk.iter().enumerate() {
                flat[i * per_image..(i + 1) * per_image].copy_from_slice(img);
            }
            let out = self.model.infer_batch(&flat)?;
            for i in 0..chunk.len() {
                scores.push(out[i * classes..(i + 1) * classes].to_vec());
            }
        }
        Ok(BatchResult { scores, modeled_device_time: None })
    }
}

// ---------------------------------------------------------------------------

/// FPGA streaming accelerator: bit-exact numerics via the phase simulator,
/// modeled service time from the cycle model.
pub struct FpgaSimBackend {
    engine: Engine,
    config: StreamConfig,
}

impl FpgaSimBackend {
    /// Build with the paper's Table-3 design point when the model is the
    /// Table-2 network, otherwise with an optimizer-derived plan.
    pub fn new(model: BcnnModel) -> Result<Self> {
        let net = model.config();
        let geoms = layer_geometry(&net);
        let params: Vec<LayerParams> = if net.name == "cifar10-table2" {
            let mut p = paper_table3_conv_params();
            for g in &geoms[net.conv.len()..] {
                p.push(paper_fc_params(g));
            }
            p
        } else {
            optimize(&net, &OptimizeOptions::default())?
                .layers
                .iter()
                .map(|l| l.params)
                .collect()
        };
        Ok(Self {
            engine: Engine::new(model)?,
            config: StreamConfig {
                freq_hz: DEFAULT_FREQ_HZ,
                params,
                pipeline: PipelineModel::default(),
                double_buffered: true,
            },
        })
    }

    pub fn stream_config(&self) -> &StreamConfig {
        &self.config
    }
}

impl Backend for FpgaSimBackend {
    fn name(&self) -> &str {
        "fpga-sim"
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchResult> {
        let report = simulate(&self.engine, &self.config, images)?;
        let modeled = Duration::from_secs_f64(report.total_cycles as f64 / self.config.freq_hz);
        Ok(BatchResult { scores: report.scores, modeled_device_time: Some(modeled) })
    }

    fn kernel(&self) -> &'static str {
        self.engine.kernel().name()
    }
}

// ---------------------------------------------------------------------------

/// Titan X analytic comparator: native numerics, modeled whole-batch time.
pub struct GpuSimBackend {
    engine: Engine,
    model: GpuModel,
    kernel: GpuKernel,
    scratch: Scratch,
    name: String,
}

impl GpuSimBackend {
    pub fn new(model: BcnnModel, kernel: GpuKernel) -> Result<Self> {
        let gpu = GpuModel::new(&model.config());
        let name = match kernel {
            GpuKernel::Xnor => "gpu-sim-xnor".to_string(),
            GpuKernel::Baseline => "gpu-sim-baseline".to_string(),
        };
        Ok(Self {
            engine: Engine::new(model)?,
            model: gpu,
            kernel,
            scratch: Scratch::default(),
            name,
        })
    }
}

impl Backend for GpuSimBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchResult> {
        let scores = images
            .iter()
            .map(|img| self.engine.infer_with_scratch(img, &mut self.scratch))
            .collect::<Result<Vec<_>>>()?;
        let modeled =
            Duration::from_secs_f64(self.model.batch_latency_s(self.kernel, images.len().max(1)));
        Ok(BatchResult { scores, modeled_device_time: Some(modeled) })
    }

    fn kernel(&self) -> &'static str {
        self.engine.kernel().name()
    }
}
