//! Serving metrics: latency histogram, throughput, batch-size stats,
//! modeled energy accounting, and the JSON snapshot served by the
//! protocol-v2 `STATS` admin frame.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::pipeline::stage::StageSnapshot;
use crate::util::json::Json;

/// Log-scale latency histogram from 1 µs to ~33 s (25 power-of-two
/// buckets: the last boundary is 2^25 µs ≈ 33.6 s).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 25], count: 0, sum_us: 0.0, max_us: 0.0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        let idx = if us < 1.0 { 0 } else { (us.log2() as usize).min(self.buckets.len() - 1) };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(self.sum_us / self.count as f64 / 1e6)
    }

    pub fn max(&self) -> Duration {
        Duration::from_secs_f64(self.max_us / 1e6)
    }

    /// Fold another histogram in (sharded metrics aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        if other.max_us > self.max_us {
            self.max_us = other.max_us;
        }
    }

    /// Approximate quantile from bucket boundaries: the upper bound of
    /// the bucket containing the quantile, clamped to the observed
    /// maximum so a sparsely filled bucket can never report a quantile
    /// above `max()` (the bound alone overshoots by up to 2x).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target.max(1) {
                return Duration::from_micros(1u64 << (i + 1)).min(self.max());
            }
        }
        self.max()
    }

    /// Bucket-wise delta relative to an earlier snapshot of the same
    /// histogram (windowed telemetry).  The per-window maximum is not
    /// recoverable from counters alone; it is approximated by the upper
    /// bound of the highest bucket that grew, clamped by the cumulative
    /// maximum — consistent with `quantile`'s bucket resolution.
    pub fn delta_since(&self, prev: &Histogram) -> Histogram {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .zip(&prev.buckets)
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        let max_us = buckets
            .iter()
            .rposition(|&b| b > 0)
            .map(|i| ((1u64 << (i + 1)) as f64).min(self.max_us))
            .unwrap_or(0.0);
        Histogram {
            buckets,
            count: self.count.saturating_sub(prev.count),
            sum_us: (self.sum_us - prev.sum_us).max(0.0),
            max_us,
        }
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub latency: Histogram,
    pub queue: Histogram,
    pub service: Histogram,
    pub requests: u64,
    pub batches: u64,
    pub sum_batch: u64,
    /// Requests that received an error reply (failed batches; nothing is
    /// silently dropped).
    pub errors: u64,
    /// Worker/stage-thread panics contained by the supervision layer
    /// (replica crashes + pipeline stage-lane deaths).
    pub crashes: u64,
    /// Replica rebuilds the supervisor completed after a crash.
    pub restarts: u64,
    /// Requests served via a degradation path instead of their original
    /// replica: pipeline batches re-run on the bit-exact engine after a
    /// stage death, plus queued requests failed out when a circuit
    /// breaker tripped (the client retries them onto a healthy shard).
    pub requests_failed_over: u64,
    /// Modeled device-busy time (simulator backends).
    pub modeled_busy: Duration,
    pub wall: Duration,
    /// Per-stage busy/stall counters for pipeline-backed models (one
    /// entry per layer stage; empty for stage-less backends).  Shards
    /// replace their own snapshot per batch; [`Metrics::merge`] sums
    /// stage-wise across replicas.
    pub stages: Vec<StageSnapshot>,
    /// Set when a fold mixed pipelines of different shapes: `stages`
    /// then holds only one shape's counters, and dashboards must not
    /// render it as a pool-wide per-stage sum.
    pub stages_mixed: bool,
    /// Name of the bitwise SIMD kernel the backend's engine dispatched to
    /// (`"scalar"`/`"avx2"`/`"avx512"`; empty when the backend has no host
    /// engine hot path).  Recorded so every `STATS`/bench snapshot says
    /// which datapath produced its numbers.
    pub kernel: String,
}

impl Metrics {
    pub fn new() -> Self {
        Self { latency: Histogram::new(), queue: Histogram::new(), service: Histogram::new(), ..Default::default() }
    }

    pub fn record_batch(
        &mut self,
        batch_size: usize,
        service: Duration,
        modeled: Option<Duration>,
    ) {
        self.batches += 1;
        self.requests += batch_size as u64;
        self.sum_batch += batch_size as u64;
        self.service.record(service);
        if let Some(m) = modeled {
            self.modeled_busy += m;
        }
    }

    pub fn record_request(&mut self, queue: Duration, latency: Duration) {
        self.queue.record(queue);
        self.latency.record(latency);
    }

    /// A whole batch failed: its requests got error replies.
    pub fn record_batch_error(&mut self, batch_size: usize, service: Duration) {
        self.batches += 1;
        self.requests += batch_size as u64;
        self.sum_batch += batch_size as u64;
        self.errors += batch_size as u64;
        self.service.record(service);
    }

    /// Fold a shard's metrics into this aggregate (wall time is set by the
    /// coordinator snapshot, not merged).
    pub fn merge(&mut self, other: &Metrics) {
        self.latency.merge(&other.latency);
        self.queue.merge(&other.queue);
        self.service.merge(&other.service);
        self.requests += other.requests;
        self.batches += other.batches;
        self.sum_batch += other.sum_batch;
        self.errors += other.errors;
        self.crashes += other.crashes;
        self.restarts += other.restarts;
        self.requests_failed_over += other.requests_failed_over;
        self.modeled_busy += other.modeled_busy;
        self.stages_mixed |= other.stages_mixed;
        if !other.stages.is_empty() {
            if self.stages.is_empty() {
                self.stages = other.stages.clone();
            } else if self.stages.len() == other.stages.len() {
                // same pipeline shape: aggregate stage-wise across shards
                for (a, b) in self.stages.iter_mut().zip(&other.stages) {
                    a.absorb(b);
                }
            } else {
                // differing shapes (mixed backends in one fold): keep ours
                // — per-stage sums across different pipelines are
                // meaningless — but flag it so consumers know the stage
                // table covers only part of the fold
                self.stages_mixed = true;
            }
        }
        if self.kernel.is_empty() {
            self.kernel = other.kernel.clone();
        } else if !other.kernel.is_empty() && self.kernel != other.kernel {
            // heterogeneous shards (e.g. one forced scalar): make it visible
            self.kernel = "mixed".into();
        }
    }

    /// Delta relative to an earlier cumulative snapshot (windowed
    /// telemetry: "what happened since the last tick").  Counters
    /// subtract; histograms subtract bucket-wise; `wall` is left zero for
    /// the caller to set to the window width; per-stage counters are
    /// omitted (stage snapshots are replaced wholesale per batch, not
    /// accumulated, so windowing them is a different mechanism).
    pub fn delta_since(&self, prev: &Metrics) -> Metrics {
        Metrics {
            latency: self.latency.delta_since(&prev.latency),
            queue: self.queue.delta_since(&prev.queue),
            service: self.service.delta_since(&prev.service),
            requests: self.requests.saturating_sub(prev.requests),
            batches: self.batches.saturating_sub(prev.batches),
            sum_batch: self.sum_batch.saturating_sub(prev.sum_batch),
            errors: self.errors.saturating_sub(prev.errors),
            crashes: self.crashes.saturating_sub(prev.crashes),
            restarts: self.restarts.saturating_sub(prev.restarts),
            requests_failed_over: self
                .requests_failed_over
                .saturating_sub(prev.requests_failed_over),
            modeled_busy: self.modeled_busy.saturating_sub(prev.modeled_busy),
            wall: Duration::ZERO,
            stages: Vec::new(),
            stages_mixed: false,
            kernel: self.kernel.clone(),
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.sum_batch as f64 / self.batches as f64
        }
    }

    /// Achieved requests/s over the recorded wall time.
    pub fn throughput(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / s
        }
    }

    /// Modeled energy (J) given a device power draw, charged for the
    /// modeled busy time only.
    pub fn modeled_energy_j(&self, power_w: f64) -> f64 {
        power_w * self.modeled_busy.as_secs_f64()
    }

    /// Median end-to-end latency.
    pub fn p50(&self) -> Duration {
        self.latency.quantile(0.5)
    }

    /// Tail end-to-end latency.
    pub fn p99(&self) -> Duration {
        self.latency.quantile(0.99)
    }

    /// JSON snapshot (stable keys; microsecond latencies) — the payload
    /// of the protocol-v2 `STATS` frame and of bench artifacts.
    pub fn to_json(&self) -> Json {
        let us = |d: Duration| Json::Num(d.as_secs_f64() * 1e6);
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("requests".into(), Json::Num(self.requests as f64));
        m.insert("errors".into(), Json::Num(self.errors as f64));
        m.insert("crashes".into(), Json::Num(self.crashes as f64));
        m.insert("restarts".into(), Json::Num(self.restarts as f64));
        m.insert(
            "requests_failed_over".into(),
            Json::Num(self.requests_failed_over as f64),
        );
        m.insert("batches".into(), Json::Num(self.batches as f64));
        m.insert("mean_batch".into(), Json::Num(self.mean_batch()));
        m.insert("throughput".into(), Json::Num(self.throughput()));
        m.insert("latency_mean_us".into(), us(self.latency.mean()));
        m.insert("latency_p50_us".into(), us(self.p50()));
        m.insert("latency_p99_us".into(), us(self.p99()));
        m.insert("latency_max_us".into(), us(self.latency.max()));
        m.insert("modeled_busy_us".into(), us(self.modeled_busy));
        if !self.kernel.is_empty() {
            m.insert("kernel".into(), Json::Str(self.kernel.clone()));
        }
        if !self.stages.is_empty() {
            let stages: Vec<Json> = self
                .stages
                .iter()
                .map(|s| {
                    let mut o: BTreeMap<String, Json> = BTreeMap::new();
                    o.insert("layer".into(), Json::Num(s.layer as f64));
                    o.insert("lanes".into(), Json::Num(s.lanes as f64));
                    o.insert("busy_us".into(), us(s.busy));
                    o.insert("stall_in_us".into(), us(s.stall_in));
                    o.insert("stall_out_us".into(), us(s.stall_out));
                    o.insert("rows_in".into(), Json::Num(s.rows_in as f64));
                    o.insert("images".into(), Json::Num(s.images as f64));
                    o.insert("xor_words".into(), Json::Num(s.xor_words as f64));
                    o.insert("popcounts".into(), Json::Num(s.popcounts as f64));
                    o.insert("bytes_moved".into(), Json::Num(s.bytes_moved as f64));
                    Json::Obj(o)
                })
                .collect();
            m.insert("stages".into(), Json::Arr(stages));
            m.insert("stages_mixed".into(), Json::Bool(self.stages_mixed));
        }
        Json::Obj(m)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} errors={} batches={} mean_batch={:.1} throughput={:.1}/s \
             latency(mean={:?} p50={:?} p99={:?} max={:?})",
            self.requests,
            self.errors,
            self.batches,
            self.mean_batch(),
            self.throughput(),
            self.latency.mean(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
            self.latency.max(),
        );
        if self.crashes > 0 || self.restarts > 0 || self.requests_failed_over > 0 {
            s.push_str(&format!(
                " crashes={} restarts={} failed_over={}",
                self.crashes, self.restarts, self.requests_failed_over
            ));
        }
        s
    }
}

// ---------------------------------------------------------------------------

/// Per-lane admission counters (QoS front-end): a point-in-time snapshot
/// of one lane's lifecycle totals, serialized into the schema-pinned
/// `"frontend"` section of the v2 `STATS` payload.  Conservation invariant:
/// every admitted request is eventually exactly one of dispatched,
/// shed_expired, or shed_overload (depth is the in-flight remainder).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneCounters {
    /// Requests accepted into the lane queue.
    pub admitted: u64,
    /// Requests handed to a shard pool.
    pub dispatched: u64,
    /// Requests shed with a typed `Expired` reply (deadline passed).
    pub shed_expired: u64,
    /// Requests shed with a typed `Overload` reply (lane at capacity or
    /// the dispatch wait bound elapsed without a free shard queue).
    pub shed_overload: u64,
    /// Current queue depth (gauge, not a counter).
    pub depth: u64,
}

impl LaneCounters {
    /// Total sheds of either kind.
    pub fn shed(&self) -> u64 {
        self.shed_expired + self.shed_overload
    }

    /// Element-wise sum (aggregating lanes across front-ends).
    pub fn merge(&self, other: &LaneCounters) -> LaneCounters {
        LaneCounters {
            admitted: self.admitted + other.admitted,
            dispatched: self.dispatched + other.dispatched,
            shed_expired: self.shed_expired + other.shed_expired,
            shed_overload: self.shed_overload + other.shed_overload,
            depth: self.depth + other.depth,
        }
    }

    /// JSON object with stable keys (pinned by the stats-schema test).
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("admitted".into(), Json::Num(self.admitted as f64));
        m.insert("depth".into(), Json::Num(self.depth as f64));
        m.insert("dispatched".into(), Json::Num(self.dispatched as f64));
        m.insert("shed_expired".into(), Json::Num(self.shed_expired as f64));
        m.insert("shed_overload".into(), Json::Num(self.shed_overload as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(h.mean() > Duration::from_micros(400));
        assert!(h.mean() < Duration::from_micros(600));
    }

    #[test]
    fn quantile_never_exceeds_observed_max() {
        // one 10 µs sample lands in bucket [8, 16): the raw bucket bound
        // would report 16 µs, 60% above anything observed
        let mut h = Histogram::new();
        h.record(Duration::from_micros(10));
        assert_eq!(h.quantile(0.5), Duration::from_micros(10));
        assert_eq!(h.quantile(0.99), Duration::from_micros(10));
        // and in general p99 <= max
        let mut h = Histogram::new();
        for i in [3u64, 90, 700, 2_500] {
            h.record(Duration::from_micros(i));
        }
        assert!(h.quantile(0.99) <= h.max());
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn histogram_delta_isolates_new_samples() {
        let mut prev = Histogram::new();
        prev.record(Duration::from_micros(100));
        let mut cur = prev.clone();
        cur.record(Duration::from_micros(5_000));
        cur.record(Duration::from_micros(6_000));
        let d = cur.delta_since(&prev);
        assert_eq!(d.count(), 2);
        assert!(d.mean() >= Duration::from_micros(5_000));
        assert!(d.quantile(0.99) >= Duration::from_micros(4_096));
        assert!(d.quantile(0.99) <= d.max());
        // no new samples: empty delta
        let none = cur.delta_since(&cur);
        assert_eq!(none.count(), 0);
        assert_eq!(none.quantile(0.99), Duration::ZERO);
        assert_eq!(none.max(), Duration::ZERO);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn merge_accumulates_shards() {
        let mut a = Metrics::new();
        a.record_batch(4, Duration::from_millis(2), None);
        a.record_request(Duration::from_millis(1), Duration::from_millis(3));
        let mut b = Metrics::new();
        b.record_batch(2, Duration::from_millis(2), Some(Duration::from_millis(1)));
        b.record_batch_error(3, Duration::from_millis(1));
        let mut total = Metrics::new();
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.requests, 9);
        assert_eq!(total.batches, 3);
        assert_eq!(total.errors, 3);
        assert_eq!(total.latency.count(), 1);
        assert_eq!(total.modeled_busy, Duration::from_millis(1));
        assert!(total.summary().contains("errors=3"));
    }

    #[test]
    fn json_snapshot_has_quantiles() {
        let mut m = Metrics::new();
        m.record_batch(2, Duration::from_millis(2), None);
        m.record_request(Duration::from_millis(1), Duration::from_millis(3));
        m.record_request(Duration::from_millis(1), Duration::from_millis(5));
        let j = m.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("errors").unwrap().as_usize().unwrap(), 0);
        let p50 = j.get("latency_p50_us").unwrap().as_f64().unwrap();
        let p99 = j.get("latency_p99_us").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0 && p99 >= p50, "p50 {p50} p99 {p99}");
    }

    #[test]
    fn stage_snapshots_merge_and_serialize() {
        let stage = |layer: usize, busy_ms: u64| StageSnapshot {
            layer,
            lanes: 2,
            busy: Duration::from_millis(busy_ms),
            stall_in: Duration::from_millis(1),
            stall_out: Duration::ZERO,
            rows_in: 8,
            images: 1,
            xor_words: 64,
            popcounts: 64,
            bytes_moved: 128,
        };
        let mut a = Metrics::new();
        a.stages = vec![stage(0, 3), stage(1, 9)];
        let mut b = Metrics::new();
        b.stages = vec![stage(0, 1), stage(1, 2)];
        let mut total = Metrics::new();
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.stages.len(), 2);
        assert_eq!(total.stages[1].busy, Duration::from_millis(11));
        assert_eq!(total.stages[0].rows_in, 16);
        assert_eq!(total.stages[0].xor_words, 128, "ledger words absorb additively");
        assert_eq!(total.stages[0].bytes_moved, 256);
        let j = total.to_json();
        let stages = j.get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[1].get("lanes").unwrap().as_usize().unwrap(), 2);
        assert!(stages[1].get("busy_us").unwrap().as_f64().unwrap() > 0.0);
        // stage-less metrics omit the key entirely
        assert!(Metrics::new().to_json().get("stages").is_err());
    }

    #[test]
    fn mixed_stage_shapes_are_flagged() {
        let stage = |layer: usize| StageSnapshot {
            layer,
            lanes: 1,
            busy: Duration::from_millis(1),
            stall_in: Duration::ZERO,
            stall_out: Duration::ZERO,
            rows_in: 4,
            images: 1,
            ..Default::default()
        };
        let mut three = Metrics::new();
        three.stages = vec![stage(0), stage(1), stage(2)];
        let mut two = Metrics::new();
        two.stages = vec![stage(0), stage(1)];
        let mut total = Metrics::new();
        total.merge(&three);
        assert!(!total.stages_mixed, "single shape: not mixed");
        total.merge(&two);
        assert!(total.stages_mixed, "differing shapes must be flagged");
        assert_eq!(total.stages.len(), 3, "keeps the first shape's counters");
        let j = total.to_json();
        assert!(j.get("stages_mixed").unwrap().as_bool().unwrap());
        // same-shape folds serialize the flag as false
        let mut clean = Metrics::new();
        clean.merge(&two);
        clean.merge(&two);
        assert!(!clean.to_json().get("stages_mixed").unwrap().as_bool().unwrap());
        // the flag survives further merges (propagates through folds)
        let mut outer = Metrics::new();
        outer.merge(&total);
        assert!(outer.stages_mixed);
        // stage-less metrics omit the flag along with the stages key
        assert!(Metrics::new().to_json().get("stages_mixed").is_err());
    }

    #[test]
    fn metrics_delta_since_subtracts_counters() {
        let mut prev = Metrics::new();
        prev.record_batch(4, Duration::from_millis(1), None);
        for _ in 0..4 {
            prev.record_request(Duration::from_micros(50), Duration::from_micros(300));
        }
        let mut cur = prev.clone();
        cur.record_batch_error(2, Duration::from_millis(1));
        cur.record_batch(2, Duration::from_millis(20), Some(Duration::from_millis(3)));
        for _ in 0..2 {
            cur.record_request(Duration::from_millis(1), Duration::from_millis(25));
        }
        cur.crashes += 1;
        let mut d = cur.delta_since(&prev);
        assert_eq!(d.requests, 4);
        assert_eq!(d.errors, 2);
        assert_eq!(d.crashes, 1);
        assert_eq!(d.batches, 2);
        assert_eq!(d.latency.count(), 2);
        assert_eq!(d.modeled_busy, Duration::from_millis(3));
        assert!(d.p99() >= Duration::from_millis(16), "window p99 reflects the window");
        assert!(d.stages.is_empty() && !d.stages_mixed);
        d.wall = Duration::from_secs(2);
        assert_eq!(d.throughput(), 2.0);
    }

    #[test]
    fn kernel_name_merges_and_serializes() {
        // empty kernel: key omitted entirely
        assert!(Metrics::new().to_json().get("kernel").is_err());
        let mut total = Metrics::new();
        let mut a = Metrics::new();
        a.kernel = "avx2".into();
        total.merge(&a);
        assert_eq!(total.kernel, "avx2");
        // same kernel across shards stays put
        total.merge(&a);
        assert_eq!(total.kernel, "avx2");
        // a kernel-less shard (e.g. pjrt) does not erase it
        total.merge(&Metrics::new());
        assert_eq!(total.kernel, "avx2");
        // heterogeneous shards are flagged, not silently picked
        let mut b = Metrics::new();
        b.kernel = "scalar".into();
        total.merge(&b);
        assert_eq!(total.kernel, "mixed");
        let j = total.to_json();
        assert_eq!(j.get("kernel").unwrap().as_str().unwrap(), "mixed");
    }

    #[test]
    fn metrics_accounting() {
        let mut m = Metrics::new();
        m.record_batch(4, Duration::from_millis(2), Some(Duration::from_millis(1)));
        m.record_batch(2, Duration::from_millis(2), Some(Duration::from_millis(1)));
        assert_eq!(m.requests, 6);
        assert_eq!(m.mean_batch(), 3.0);
        assert!((m.modeled_energy_j(8.2) - 8.2 * 0.002).abs() < 1e-9);
        m.wall = Duration::from_secs(2);
        assert_eq!(m.throughput(), 3.0);
    }

    #[test]
    fn lane_counters_merge_and_json() {
        let a = LaneCounters {
            admitted: 10,
            dispatched: 7,
            shed_expired: 2,
            shed_overload: 1,
            depth: 0,
        };
        let b =
            LaneCounters { admitted: 4, dispatched: 1, shed_expired: 0, shed_overload: 0, depth: 3 };
        let sum = a.merge(&b);
        assert_eq!(sum.admitted, 14);
        assert_eq!(sum.shed(), 3);
        assert_eq!(sum.depth, 3);
        // conservation: admitted == dispatched + sheds + depth
        assert_eq!(sum.admitted, sum.dispatched + sum.shed() + sum.depth);
        let j = sum.to_json();
        let keys: Vec<&str> = j.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
        assert_eq!(keys, ["admitted", "depth", "dispatched", "shed_expired", "shed_overload"]);
    }
}
