//! L3 serving coordinator.
//!
//! The paper's serving claim — FPGA throughput is batch-insensitive, so it
//! wins for online individual requests (§6.3, the Baidu batch-8-to-16
//! workload) — is an end-to-end *serving* property, so the reproduction
//! ships a real request path: a dynamic [`batcher`] (max-batch + deadline,
//! vLLM-router-style), pluggable [`backend`]s (native engine, PJRT
//! executable, FPGA-simulator timing, GPU-model timing), per-request
//! [`metrics`] (latency histograms, throughput, errors, energy), a
//! *sharded* thread-pool [`server`] — N worker shards, each owning a
//! backend replica, fed from bounded queues with explicit backpressure —
//! an optional TCP front-end, and a Poisson/closed-loop [`workload`]
//! generator.
//!
//! The sharding mirrors how FINN-style BNN accelerators scale by
//! replicating compute engines: host software must be as spatially
//! parallel as the datapath or it becomes the bottleneck the paper's
//! Fig. 7 says should not exist.  Data flow:
//!
//! `client -> dispatch (round-robin + least-loaded) -> bounded shard queue
//! -> batcher -> worker thread -> backend replica -> reply channel`
//!
//! No tokio in the offline crate cache — the TCP front-end is a hand-rolled
//! epoll [`reactor`] (nonblocking multiplexed connections, incremental
//! frame decoding, write backpressure) feeding a two-lane [`qos`] admission
//! scheduler; the shard pool itself stays std threads + channels, which for
//! this workload (CPU-bound inference, one worker per replica) is the same
//! architecture without the executor.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod qos;
pub mod reactor;
pub mod request;
pub mod server;
pub mod supervisor;
pub mod workload;

pub use backend::{
    Backend, BackendFactory, BatchResult, FpgaSimBackend, GpuSimBackend, NativeBackend,
    PjrtBackend,
};
// the row-streaming layer-pipeline backend lives in `crate::pipeline` but
// is served through this coordinator like every other backend
pub use crate::pipeline::PipelineBackend;
pub use batcher::{BatchPolicy, Batcher, Msg};
pub use metrics::{LaneCounters, Metrics};
pub use qos::{
    frontend_json, frontend_snapshot, parse_qos_weights, FrontendConfig, FrontendSnapshot,
    FrontendStats, Lane, QosAdmission, QosConfig,
};
pub use reactor::reactor_supported;
pub use request::{InferError, InferErrorKind, InferReply, InferRequest, ReplyTo, SubmitError};
pub use server::{
    serve_tcp, serve_tcp_frontend, serve_tcp_threaded, Client, Coordinator, CoordinatorConfig,
    TcpClient, MAX_WIRE_VALUES,
};
pub use supervisor::{
    PoolHealth, RestartPolicy, ShardHealth, ShardHealthSnapshot, ShardState,
};
