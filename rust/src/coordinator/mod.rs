//! L3 serving coordinator.
//!
//! The paper's serving claim — FPGA throughput is batch-insensitive, so it
//! wins for online individual requests (§6.3, the Baidu batch-8-to-16
//! workload) — is an end-to-end *serving* property, so the reproduction
//! ships a real request path: a dynamic [`batcher`] (max-batch + deadline,
//! vLLM-router-style), pluggable [`backend`]s (native engine, PJRT
//! executable, FPGA-simulator timing, GPU-model timing), per-request
//! [`metrics`] (latency histograms, throughput, energy), a thread-based
//! [`server`] with an optional TCP front-end, and a Poisson/closed-loop
//! [`workload`] generator.
//!
//! No tokio in the offline crate cache — the event loop is std threads +
//! channels, which for this workload (CPU-bound inference, one worker per
//! backend) is the same architecture without the executor.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod workload;

pub use backend::{Backend, BatchResult, FpgaSimBackend, GpuSimBackend, NativeBackend, PjrtBackend};
pub use batcher::{BatchPolicy, Batcher, Msg};
pub use request::{InferReply, InferRequest};
pub use server::{Client, Coordinator, CoordinatorConfig};
