//! Chrome trace-event JSON export: turn the span rings into a
//! `trace.json` that `chrome://tracing` / Perfetto loads directly.
//!
//! Layout: one process (`pid` 1), one track (`tid`) per ring — shard
//! rings (`pool{N}/shard{S}`) and pipeline-stage rings
//! (`pipe{N}/stage{L}`) side by side, named via `thread_name` metadata
//! events.  Every span is a complete event (`"ph":"X"`) with
//! microsecond `ts`/`dur` on the shared monotonic clock, and carries
//! `trace_id` in `args` so one request's journey can be followed across
//! tracks end-to-end.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::util::json::Json;

use super::ring::{rings, SpanEvent, SpanKind, SpanRing};

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Export every live ring in the process (the `OP_TRACE` payload).
pub fn chrome_trace_json() -> Json {
    chrome_trace_for(&rings())
}

/// Export a specific set of rings (tests; scoped dumps).
pub fn chrome_trace_for(selected: &[Arc<SpanRing>]) -> Json {
    let mut tracks: Vec<&Arc<SpanRing>> = selected.iter().collect();
    tracks.sort_by(|a, b| a.label().cmp(b.label()));
    let mut events: Vec<Json> = Vec::new();
    for (i, ring) in tracks.iter().enumerate() {
        let tid = (i + 1) as f64;
        events.push(obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid)),
            ("args", obj(vec![("name", Json::Str(ring.label().to_string()))])),
        ]));
        for ev in ring.snapshot() {
            events.push(span_json(&ev, tid));
        }
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

fn span_json(ev: &SpanEvent, tid: f64) -> Json {
    // Stage spans get per-layer names so Perfetto's aggregation view
    // groups by layer; everything else uses the kind label directly.
    let name = match ev.kind {
        SpanKind::Stage => format!("stage{}", ev.layer.unwrap_or(0)),
        k => k.label().to_string(),
    };
    let mut args = vec![
        ("trace_id", Json::Num(ev.trace_id as f64)),
        ("shard", Json::Num(f64::from(ev.shard))),
    ];
    if let Some(layer) = ev.layer {
        args.push(("layer", Json::Num(f64::from(layer))));
    }
    if ev.batch > 0 {
        args.push(("batch", Json::Num(f64::from(ev.batch))));
    }
    obj(vec![
        ("name", Json::Str(name)),
        ("cat", Json::Str(ev.kind.label().into())),
        ("ph", Json::Str("X".into())),
        ("ts", Json::Num(ev.t_start_ns as f64 / 1e3)),
        ("dur", Json::Num(ev.t_end_ns.saturating_sub(ev.t_start_ns) as f64 / 1e3)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(tid)),
        ("args", obj(args)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_names_tracks_and_spans() {
        let _g = crate::obs::ring::test_guard();
        let ring = SpanRing::new("test/export-track", 8);
        ring.record(&SpanEvent {
            trace_id: 42,
            kind: SpanKind::Queue,
            t_start_ns: 1_000,
            t_end_ns: 3_500,
            shard: 1,
            layer: None,
            batch: 0,
        });
        ring.record(&SpanEvent {
            trace_id: 42,
            kind: SpanKind::Stage,
            t_start_ns: 4_000,
            t_end_ns: 9_000,
            shard: 1,
            layer: Some(2),
            batch: 0,
        });
        let j = chrome_trace_for(&[ring]);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3, "metadata + 2 spans");
        // track metadata names the ring
        let meta = &events[0];
        assert_eq!(meta.get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(
            meta.get("args").unwrap().get("name").unwrap().as_str().unwrap(),
            "test/export-track"
        );
        // complete events in microseconds, correlated by trace_id
        let queue = &events[1];
        assert_eq!(queue.get("name").unwrap().as_str().unwrap(), "queue");
        assert_eq!(queue.get("ph").unwrap().as_str().unwrap(), "X");
        assert!((queue.get("ts").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!((queue.get("dur").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        assert_eq!(
            queue.get("args").unwrap().get("trace_id").unwrap().as_usize().unwrap(),
            42
        );
        let stage = &events[2];
        assert_eq!(stage.get("name").unwrap().as_str().unwrap(), "stage2");
        assert_eq!(stage.get("cat").unwrap().as_str().unwrap(), "stage");
        assert_eq!(stage.get("args").unwrap().get("layer").unwrap().as_usize().unwrap(), 2);
        // the whole document round-trips through the parser
        let text = j.to_string();
        let parsed = Json::parse(&text).expect("exported trace JSON parses");
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), 3);
    }
}
