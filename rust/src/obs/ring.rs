//! Lock-free span ring buffers: the always-on tracing substrate.
//!
//! Every shard worker and every pipeline stage owns one [`SpanRing`] — a
//! fixed-capacity, overwrite-oldest buffer of [`SpanEvent`]s written by
//! exactly one thread and snapshotted concurrently by the exporter.  The
//! write path is wait-free: a relaxed cursor `fetch_add` picks the slot,
//! a seqlock (odd sequence = mid-write) guards the payload words, and the
//! whole record is plain atomics — no locks, no allocation, no `unsafe`.
//! When tracing is disabled the cost collapses to one relaxed load.
//!
//! Rings self-register into a process-global registry (as `Weak`, so a
//! dropped pool deregisters naturally); [`rings`] hands the exporter every
//! live ring without any plumbing through the serving stack.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

/// What a span measures.  One request produces one `Admission`, `Queue`,
/// `Batch` and `Reply` span on its shard's ring plus one `Stage` span per
/// pipeline layer (pipeline backends only).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// `Client::submit`: dispatch decision + queue handoff.
    Admission,
    /// Time the request sat in the shard queue before its batch formed.
    Queue,
    /// Backend execution of the batch the request rode in.
    Batch,
    /// One image flowing through one pipeline stage (row-streaming).
    Stage,
    /// Reply fan-out back to the submitting client.
    Reply,
    /// Reactor front-end: first byte of a frame to its complete decode.
    Read,
    /// QoS admission: lane wait from admit to shard dispatch.
    Dispatch,
    /// Reactor front-end: completion delivery to wire write staging.
    Write,
}

impl SpanKind {
    /// Stable label used as the Chrome trace-event `name`.
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::Admission => "admission",
            SpanKind::Queue => "queue",
            SpanKind::Batch => "batch",
            SpanKind::Stage => "stage",
            SpanKind::Reply => "reply",
            SpanKind::Read => "read",
            SpanKind::Dispatch => "dispatch",
            SpanKind::Write => "write",
        }
    }

    fn encode(self) -> u64 {
        match self {
            SpanKind::Admission => 0,
            SpanKind::Queue => 1,
            SpanKind::Batch => 2,
            SpanKind::Stage => 3,
            SpanKind::Reply => 4,
            SpanKind::Read => 5,
            SpanKind::Dispatch => 6,
            SpanKind::Write => 7,
        }
    }

    fn decode(w: u64) -> Option<SpanKind> {
        Some(match w {
            0 => SpanKind::Admission,
            1 => SpanKind::Queue,
            2 => SpanKind::Batch,
            3 => SpanKind::Stage,
            4 => SpanKind::Reply,
            5 => SpanKind::Read,
            6 => SpanKind::Dispatch,
            7 => SpanKind::Write,
            _ => return None,
        })
    }
}

/// One recorded span.  Timestamps are nanoseconds on the process-wide
/// monotonic clock ([`now_ns`]), so spans from different rings line up on
/// one timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Request identity, minted at admission ([`mint_trace_id`]) and
    /// threaded end-to-end (coordinator → pipeline → wire reply).
    pub trace_id: u64,
    pub kind: SpanKind,
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    /// Shard index (coordinator rings) or pipeline instance (stage rings).
    pub shard: u32,
    /// Layer index for `Stage` spans; `None` elsewhere.
    pub layer: Option<u32>,
    /// Batch size for `Batch` spans; 0 elsewhere.
    pub batch: u32,
}

const WORDS: usize = 6;
const LAYER_NONE: u64 = u64::MAX;

/// One seqlock-guarded slot.  `seq` is even when stable, odd mid-write;
/// 0 means never written.  Readers that observe an odd or changed `seq`
/// skip the slot (the writer overwrote it mid-read — by construction the
/// oldest data in the ring, so dropping it is the right call).
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; WORDS],
}

impl Slot {
    fn empty() -> Self {
        Slot { seq: AtomicU64::new(0), words: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn write(&self, ev: &SpanEvent) {
        let seq = self.seq.load(Ordering::Relaxed);
        self.seq.store(seq.wrapping_add(1), Ordering::Release); // odd: mid-write
        let words = [
            ev.trace_id,
            ev.kind.encode(),
            ev.t_start_ns,
            ev.t_end_ns,
            (u64::from(ev.shard) << 32) | u64::from(ev.batch),
            ev.layer.map_or(LAYER_NONE, u64::from),
        ];
        for (w, v) in self.words.iter().zip(words) {
            w.store(v, Ordering::Relaxed);
        }
        self.seq.store(seq.wrapping_add(2), Ordering::Release); // even: stable
    }

    fn read(&self) -> Option<SpanEvent> {
        let before = self.seq.load(Ordering::Acquire);
        if before == 0 || before % 2 == 1 {
            return None;
        }
        let mut words = [0u64; WORDS];
        for (v, w) in words.iter_mut().zip(&self.words) {
            *v = w.load(Ordering::Relaxed);
        }
        if self.seq.load(Ordering::Acquire) != before {
            return None; // torn read: writer lapped us
        }
        Some(SpanEvent {
            trace_id: words[0],
            kind: SpanKind::decode(words[1])?,
            t_start_ns: words[2],
            t_end_ns: words[3],
            shard: (words[4] >> 32) as u32,
            batch: words[4] as u32,
            layer: if words[5] == LAYER_NONE { None } else { Some(words[5] as u32) },
        })
    }
}

/// Default span capacity per ring (per shard / per stage).  At ~500 B/s
/// of spans per slot this holds the last few thousand requests — plenty
/// for "export what just happened" while bounding memory hard.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// A single-writer, multi-reader span ring: fixed capacity, overwrite
/// oldest, atomic write cursor.
pub struct SpanRing {
    label: String,
    slots: Vec<Slot>,
    cursor: AtomicU64,
}

impl SpanRing {
    /// Create a ring and register it with the global exporter registry.
    /// `label` becomes the track name in the Chrome trace (one track per
    /// ring, e.g. `pool1/shard0` or `pipe3/stage2`).
    pub fn new(label: impl Into<String>, capacity: usize) -> Arc<SpanRing> {
        let ring = Arc::new(SpanRing {
            label: label.into(),
            slots: (0..capacity.max(1)).map(|_| Slot::empty()).collect(),
            cursor: AtomicU64::new(0),
        });
        registry().lock().unwrap_or_else(|e| e.into_inner()).push(Arc::downgrade(&ring));
        ring
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total spans ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Record a span.  Wait-free; a no-op (one relaxed load) while tracing
    /// is disabled.  Intended for the ring's single owning writer thread —
    /// concurrent writers stay memory-safe but may interleave slot words.
    pub fn record(&self, ev: &SpanEvent) {
        if !enabled() {
            return;
        }
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) as usize % self.slots.len();
        self.slots[i].write(ev);
    }

    /// Snapshot every stable slot, oldest-first by start time.  Slots mid
    /// overwrite are skipped, never blocked on.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out: Vec<SpanEvent> = self.slots.iter().filter_map(Slot::read).collect();
        out.sort_by_key(|e| (e.t_start_ns, e.t_end_ns, e.trace_id));
        out
    }
}

fn registry() -> &'static Mutex<Vec<Weak<SpanRing>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Weak<SpanRing>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Every live ring in the process (dropped pools prune themselves).
pub fn rings() -> Vec<Arc<SpanRing>> {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.retain(|w| w.strong_count() > 0);
    reg.iter().filter_map(Weak::upgrade).collect()
}

// --- global enable/disable gate ------------------------------------------
//
// Same shape as `util::faults::MODE`: an AtomicU8 whose relaxed load is the
// entire disarmed fast path.  Tracing defaults ON (the ISSUE's "always-on,
// low-overhead"); `BCNN_TRACE=off|0|false` in the environment or
// `set_enabled(false)` turns it off.

const MODE_UNINIT: u8 = 0;
const MODE_OFF: u8 = 1;
const MODE_ON: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);

/// Is span recording armed?  One relaxed load on the hot path.
#[inline]
pub fn enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        MODE_ON => true,
        MODE_OFF => false,
        _ => init_mode(),
    }
}

#[cold]
fn init_mode() -> bool {
    let on = match std::env::var("BCNN_TRACE") {
        Ok(v) => !matches!(v.trim().to_ascii_lowercase().as_str(), "off" | "0" | "false"),
        Err(_) => true,
    };
    MODE.store(if on { MODE_ON } else { MODE_OFF }, Ordering::Relaxed);
    on
}

/// Arm or disarm span recording process-wide (benches toggle this to
/// measure the observer effect).
pub fn set_enabled(on: bool) {
    MODE.store(if on { MODE_ON } else { MODE_OFF }, Ordering::Relaxed);
}

/// Nanoseconds on the process-wide monotonic clock (epoch = first call).
/// Every span on every ring uses this clock, so the exporter can lay them
/// on a single timeline.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Mint a process-unique trace ID (minted at admission, threaded through
/// every span and the protocol-v2 reply).  0 is reserved for "untraced".
pub fn mint_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Mint a process-unique instance number for ring labels (`pool{N}`,
/// `pipe{N}`), so replicas and restarts get distinct tracks.
pub fn next_instance_id() -> u32 {
    static NEXT: AtomicU32 = AtomicU32::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// A bounded map from "k-th image fed into a pipeline" to its trace ID.
///
/// The feeder writes `set(k, id)` before streaming image `k`'s rows; each
/// stage counts the images it has flushed and reads `get(k)` to label its
/// span.  Indexing is absolute (mod capacity), which is safe because the
/// pipeline's admission window keeps in-flight images far below capacity —
/// by the time slot `k % cap` is reused, image `k` has long since left
/// every stage.
pub struct TraceLog {
    ids: Vec<AtomicU64>,
}

impl TraceLog {
    pub fn new(capacity: usize) -> Self {
        TraceLog { ids: (0..capacity.max(1)).map(|_| AtomicU64::new(0)).collect() }
    }

    pub fn set(&self, k: u64, trace_id: u64) {
        self.ids[k as usize % self.ids.len()].store(trace_id, Ordering::Release);
    }

    pub fn get(&self, k: u64) -> u64 {
        self.ids[k as usize % self.ids.len()].load(Ordering::Acquire)
    }
}

/// Per-stage span recorder handed into the stage-lane loops: a ring, the
/// shared feeder trace log, and this stage's identity.  One `record_image`
/// call per image flush — zero cost per row.
pub struct StageTracer {
    ring: Arc<SpanRing>,
    log: Arc<TraceLog>,
    instance: u32,
    layer: u32,
}

impl StageTracer {
    pub fn new(ring: Arc<SpanRing>, log: Arc<TraceLog>, instance: u32, layer: u32) -> Self {
        StageTracer { ring, log, instance, layer }
    }

    /// Record the span for the `image_index`-th image through this stage
    /// (start captured by the lane at the image's first row).
    pub fn record_image(&self, image_index: u64, t_start_ns: u64) {
        if !enabled() {
            return;
        }
        self.ring.record(&SpanEvent {
            trace_id: self.log.get(image_index),
            kind: SpanKind::Stage,
            t_start_ns,
            t_end_ns: now_ns(),
            shard: self.instance,
            layer: Some(self.layer),
            batch: 0,
        });
    }
}

/// `set_enabled` is process-global and unit tests run concurrently: every
/// test that records spans or toggles the gate serializes on this lock
/// (and re-arms tracing, in case a sibling left it off).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    set_enabled(true);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    fn ev(trace_id: u64, kind: SpanKind, t0: u64, t1: u64) -> SpanEvent {
        SpanEvent { trace_id, kind, t_start_ns: t0, t_end_ns: t1, shard: 3, layer: None, batch: 2 }
    }

    #[test]
    fn record_and_snapshot_roundtrip() {
        let _g = armed();
        let ring = SpanRing::new("test/roundtrip", 8);
        ring.record(&ev(7, SpanKind::Queue, 100, 200));
        ring.record(&SpanEvent { layer: Some(4), ..ev(8, SpanKind::Stage, 150, 300) });
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0], ev(7, SpanKind::Queue, 100, 200));
        assert_eq!(snap[1].layer, Some(4));
        assert_eq!(snap[1].shard, 3);
        assert_eq!(snap[1].batch, 2);
        assert_eq!(ring.recorded(), 2);
    }

    #[test]
    fn overwrites_oldest_at_capacity() {
        let _g = armed();
        let ring = SpanRing::new("test/overwrite", 4);
        for i in 0..10u64 {
            ring.record(&ev(i, SpanKind::Batch, i * 10, i * 10 + 5));
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        let ids: Vec<u64> = snap.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "only the newest capacity-many survive");
        assert_eq!(ring.recorded(), 10);
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = armed();
        let ring = SpanRing::new("test/disabled", 4);
        set_enabled(false);
        ring.record(&ev(1, SpanKind::Reply, 1, 2));
        set_enabled(true);
        assert_eq!(ring.recorded(), 0);
        assert!(ring.snapshot().is_empty());
        ring.record(&ev(2, SpanKind::Reply, 3, 4));
        assert_eq!(ring.recorded(), 1);
    }

    #[test]
    fn registry_drops_dead_rings() {
        let label = "test/registry-lifetime";
        {
            let _ring = SpanRing::new(label, 2);
            assert!(rings().iter().any(|r| r.label() == label));
        }
        assert!(!rings().iter().any(|r| r.label() == label));
    }

    #[test]
    fn trace_log_wraps_by_capacity() {
        let log = TraceLog::new(4);
        log.set(0, 100);
        log.set(5, 105); // wraps onto slot 1
        assert_eq!(log.get(0), 100);
        assert_eq!(log.get(5), 105);
        assert_eq!(log.get(1), 105, "absolute indexing is mod capacity");
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn clock_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
