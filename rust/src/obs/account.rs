//! Performance accounting: reconcile measured stage counters against the
//! paper's analytical model (eqs. 9–12).
//!
//! The paper's whole argument is performance accounting — eq. 11 predicts
//! per-layer cycles, eq. 12 turns the bottleneck layer into system FPS,
//! and Tables 3–5 check the model against the Vivado-HLS measurement.
//! This module runs the same methodology on the host pipeline: it takes
//! one [`StageSnapshot`] per stage (busy/stall wall clock + the
//! [`crate::obs::profile`] work ledger), maps each stage onto its
//! [`LayerGeom`], and reports per layer:
//!
//! * **utilization** — busy ÷ (busy + stall_in + stall_out), the share of
//!   the stage's wall clock spent computing.  Guaranteed in `(0, 1]`
//!   whenever the stage did any work; a low value with high `stall_in`
//!   means upstream starvation, with high `stall_out` downstream
//!   backpressure — eq. 12's "the slowest layer sets the phase" made
//!   visible per stage.
//! * **roofline bound class** — arithmetic intensity (bit-ops per byte
//!   moved, from the ledger) against [`BALANCE_BIT_OPS_PER_BYTE`]:
//!   conv layers reuse weight bytes across the spatial plane and land
//!   compute-bound; FC layers touch every weight byte once and land
//!   memory-bound (§5.3 is the paper hitting the same wall: FC BRAM
//!   bandwidth, not XNOR lanes, sizes the FC pipeline).
//! * **model-vs-measured** — measured ns/image against `cycle_est`
//!   (eq. 11, at the stage's actual lane count) and `cycle_real` cycles
//!   at a reference clock; the ratio is the host's "achieved fraction of
//!   model speed", and the measured bottleneck (max busy/image) is
//!   checked against the eq.-12 prediction (max `cycle_est`).

use std::time::Duration;

use anyhow::{bail, Result};

use crate::fpga::timing::{cycle_est, cycle_real, LayerParams, PipelineModel};
use crate::fpga::{layer_geometry, LayerGeom, DEFAULT_FREQ_HZ};
use crate::model::NetConfig;
use crate::obs::profile::{stage_work, StageWork};
use crate::pipeline::StageSnapshot;
use crate::util::json::Json;

/// Roofline balance point in bit-operations per byte moved.  CAL: one
/// packed 64-bit word costs 128 bit-ops (64 XNOR + 64 popcount-accumulate)
/// against 16 bytes touched (8 weight + 8 activation) when nothing is
/// reused — 8 bit-ops/byte; full spatial reuse pushes conv layers two to
/// three orders of magnitude higher.  64 sits between the FC plateau
/// (~16, see `profile::tests::fc_intensity_sits_near_its_closed_form`)
/// and the conv floor, so the classifier splits the two families the way
/// §5.3 does (FC limited by weight bandwidth, conv by lanes).
pub const BALANCE_BIT_OPS_PER_BYTE: f64 = 64.0;

/// Which roofline regime a layer sits in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// Intensity above the balance point: lanes limit throughput.
    Compute,
    /// Intensity below the balance point: bytes limit throughput.
    Memory,
}

impl Bound {
    pub fn label(self) -> &'static str {
        match self {
            Bound::Compute => "compute",
            Bound::Memory => "memory",
        }
    }
}

/// Classify an arithmetic intensity against the balance point.
pub fn classify(intensity: f64) -> Bound {
    if intensity >= BALANCE_BIT_OPS_PER_BYTE {
        Bound::Compute
    } else {
        Bound::Memory
    }
}

/// Occupancy utilization of one stage: busy ÷ (busy + stalls).  `None`
/// until the stage has recorded any wall clock at all; otherwise in
/// `(0, 1]` whenever `busy > 0`.
pub fn utilization(busy: Duration, stall_in: Duration, stall_out: Duration) -> Option<f64> {
    let total = busy + stall_in + stall_out;
    if total.is_zero() {
        return None;
    }
    Some(busy.as_secs_f64() / total.as_secs_f64())
}

/// One layer's reconciled account: the measured side (ledger + wall
/// clock), the model side (eqs. 9/11 + `Cycle_r`), and the derived
/// utilization / roofline verdicts.
#[derive(Debug, Clone)]
pub struct LayerAccount {
    /// 0-based stage index (= layer position in the pipeline).
    pub layer: usize,
    /// Paper-style layer name ("Conv 1", "FC 2", ...).
    pub name: String,
    pub lanes: usize,
    pub images: u64,
    pub rows_in: u64,
    pub xor_words: u64,
    pub popcounts: u64,
    pub bytes_moved: u64,
    pub busy: Duration,
    pub stall_in: Duration,
    pub stall_out: Duration,
    /// Occupancy in `(0, 1]` (`None` before any wall clock accrues).
    pub utilization: Option<f64>,
    /// Ledger-predicted per-image work constants for this layer.
    pub work: StageWork,
    /// eq. 11 cycles/image at this stage's actual lane count.
    pub cycles_est: u64,
    /// `Cycle_r` microarchitecture-model cycles/image, same lanes.
    pub cycles_real: u64,
    /// Measured busy ns per image (`None` until an image completes).
    pub ns_per_image: Option<f64>,
    /// Measured ÷ model ns/image at the reference clock (> 1 means the
    /// host runs slower than the eq.-11 bound, as it must).
    pub model_ratio: Option<f64>,
    pub intensity: f64,
    pub bound: Bound,
}

/// The reconciled report for one model's pipeline.
#[derive(Debug, Clone)]
pub struct AccountReport {
    pub layers: Vec<LayerAccount>,
    /// Stage with the highest measured busy/image (`None` until any
    /// stage completes an image).
    pub measured_bottleneck: Option<usize>,
    /// Stage with the highest eq.-11 `cycles_est` at actual lane counts.
    pub predicted_bottleneck: usize,
    /// Reference clock used to turn model cycles into seconds.
    pub freq_hz: f64,
}

impl AccountReport {
    /// Did the measurement land on the stage eq. 12 predicts?
    pub fn bottleneck_match(&self) -> bool {
        self.measured_bottleneck == Some(self.predicted_bottleneck)
    }

    /// Serialize for the `OP_PROFILE` wire frame / `BENCH_profile.json`.
    /// Raw cumulative counters are included so pollers can difference two
    /// reports into a windowed view (`repro profile --duration`).
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                let mut m = std::collections::BTreeMap::new();
                let mut put = |k: &str, v: Json| {
                    m.insert(k.to_string(), v);
                };
                put("layer", Json::Num(l.layer as f64));
                put("name", Json::Str(l.name.clone()));
                put("lanes", Json::Num(l.lanes as f64));
                put("images", Json::Num(l.images as f64));
                put("rows_in", Json::Num(l.rows_in as f64));
                put("xor_words", Json::Num(l.xor_words as f64));
                put("popcounts", Json::Num(l.popcounts as f64));
                put("bytes_moved", Json::Num(l.bytes_moved as f64));
                put("busy_us", Json::Num(l.busy.as_secs_f64() * 1e6));
                put("stall_in_us", Json::Num(l.stall_in.as_secs_f64() * 1e6));
                put("stall_out_us", Json::Num(l.stall_out.as_secs_f64() * 1e6));
                put(
                    "utilization",
                    l.utilization.map(Json::Num).unwrap_or(Json::Null),
                );
                put("cycles_est", Json::Num(l.cycles_est as f64));
                put("cycles_real", Json::Num(l.cycles_real as f64));
                put("ns_per_image", l.ns_per_image.map(Json::Num).unwrap_or(Json::Null));
                put("model_ratio", l.model_ratio.map(Json::Num).unwrap_or(Json::Null));
                put("intensity", Json::Num(l.intensity));
                put("bound", Json::Str(l.bound.label().to_string()));
                Json::Obj(m)
            })
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("layers".to_string(), Json::Arr(layers));
        m.insert(
            "measured_bottleneck".to_string(),
            self.measured_bottleneck.map(|i| Json::Num(i as f64)).unwrap_or(Json::Null),
        );
        m.insert("predicted_bottleneck".to_string(), Json::Num(self.predicted_bottleneck as f64));
        m.insert("bottleneck_match".to_string(), Json::Bool(self.bottleneck_match()));
        m.insert("freq_hz".to_string(), Json::Num(self.freq_hz));
        m.insert(
            "balance_bit_ops_per_byte".to_string(),
            Json::Num(BALANCE_BIT_OPS_PER_BYTE),
        );
        Json::Obj(m)
    }
}

/// Reconcile one model's measured stage snapshots against its analytical
/// model at the paper's reference clock ([`DEFAULT_FREQ_HZ`]).
pub fn reconcile(config: &NetConfig, stages: &[StageSnapshot]) -> Result<AccountReport> {
    reconcile_at(config, stages, DEFAULT_FREQ_HZ)
}

/// [`reconcile`] with an explicit reference clock.
pub fn reconcile_at(
    config: &NetConfig,
    stages: &[StageSnapshot],
    freq_hz: f64,
) -> Result<AccountReport> {
    let geoms = layer_geometry(config);
    if stages.len() != geoms.len() {
        bail!(
            "stage count {} does not match network '{}' with {} layers",
            stages.len(),
            config.name,
            geoms.len()
        );
    }
    if !(freq_hz.is_finite() && freq_hz > 0.0) {
        bail!("reference clock must be positive and finite, got {freq_hz}");
    }
    let work = stage_work(config);
    let pipeline = PipelineModel::default();
    let mut layers = Vec::with_capacity(geoms.len());
    for ((snap, geom), w) in stages.iter().zip(&geoms).zip(&work) {
        let lanes = snap.lanes.max(1);
        let params = LayerParams { uf: 1, p: lanes, ii: 1 };
        let cycles_est = cycle_est(geom, &params);
        let cycles_real = cycle_real(geom, &params, &pipeline);
        let ns_per_image = (snap.images > 0)
            .then(|| snap.busy.as_nanos() as f64 / snap.images as f64);
        let model_ns = cycles_est as f64 / freq_hz * 1e9;
        let model_ratio = ns_per_image.map(|m| m / model_ns.max(f64::MIN_POSITIVE));
        layers.push(LayerAccount {
            layer: snap.layer,
            name: geom.name.clone(),
            lanes: snap.lanes,
            images: snap.images,
            rows_in: snap.rows_in,
            xor_words: snap.xor_words,
            popcounts: snap.popcounts,
            bytes_moved: snap.bytes_moved,
            busy: snap.busy,
            stall_in: snap.stall_in,
            stall_out: snap.stall_out,
            utilization: utilization(snap.busy, snap.stall_in, snap.stall_out),
            work: *w,
            cycles_est,
            cycles_real,
            ns_per_image,
            model_ratio,
            intensity: w.intensity(),
            bound: classify(w.intensity()),
        });
    }
    let measured_bottleneck = layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.ns_per_image.is_some())
        .max_by(|(_, a), (_, b)| {
            a.ns_per_image
                .unwrap_or(0.0)
                .total_cmp(&b.ns_per_image.unwrap_or(0.0))
        })
        .map(|(i, _)| i);
    let predicted_bottleneck = layers
        .iter()
        .enumerate()
        .max_by_key(|(_, l)| l.cycles_est)
        .map(|(i, _)| i)
        .unwrap_or(0);
    Ok(AccountReport { layers, measured_bottleneck, predicted_bottleneck, freq_hz })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(layer: usize, lanes: usize, busy_ms: u64, stall_ms: u64, images: u64) -> StageSnapshot {
        StageSnapshot {
            layer,
            lanes,
            busy: Duration::from_millis(busy_ms),
            stall_in: Duration::from_millis(stall_ms),
            stall_out: Duration::ZERO,
            rows_in: images * 8,
            images,
            ..Default::default()
        }
    }

    #[test]
    fn utilization_is_occupancy_in_unit_interval() {
        assert_eq!(utilization(Duration::ZERO, Duration::ZERO, Duration::ZERO), None);
        let u = utilization(
            Duration::from_millis(30),
            Duration::from_millis(60),
            Duration::from_millis(10),
        )
        .unwrap();
        assert!((u - 0.3).abs() < 1e-9);
        let full = utilization(Duration::from_millis(5), Duration::ZERO, Duration::ZERO).unwrap();
        assert!((full - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconcile_rejects_mismatched_stage_count() {
        let cfg = NetConfig::tiny();
        assert!(reconcile(&cfg, &[]).is_err());
    }

    #[test]
    fn bottlenecks_and_bounds_line_up() {
        let cfg = NetConfig::tiny();
        let n = layer_geometry(&cfg).len();
        // stage 1 does the most busy work per image -> measured bottleneck
        let stages: Vec<StageSnapshot> = (0..n)
            .map(|l| snap(l, 1, if l == 1 { 500 } else { 50 }, 100, 10))
            .collect();
        let report = reconcile(&cfg, &stages).unwrap();
        assert_eq!(report.measured_bottleneck, Some(1));
        for l in &report.layers {
            let u = l.utilization.expect("stages have wall clock");
            assert!(u > 0.0 && u <= 1.0, "utilization {u} out of (0,1]");
            assert!(l.cycles_est > 0 && l.cycles_real >= l.cycles_est / 2);
        }
        // uniform lanes: the eq.-11 prediction is the largest cycle_conv
        let geoms = layer_geometry(&cfg);
        let expect = geoms
            .iter()
            .enumerate()
            .max_by_key(|(_, g)| g.outputs() * g.cnum as u64)
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(report.predicted_bottleneck, expect);
    }

    #[test]
    fn report_json_has_pinned_shape() {
        let cfg = NetConfig::tiny();
        let n = layer_geometry(&cfg).len();
        let stages: Vec<StageSnapshot> = (0..n).map(|l| snap(l, 2, 100, 50, 4)).collect();
        let report = reconcile(&cfg, &stages).unwrap();
        let json = report.to_json();
        let keys: Vec<&String> = json.as_obj().unwrap().keys().collect();
        assert_eq!(
            keys,
            [
                "balance_bit_ops_per_byte",
                "bottleneck_match",
                "freq_hz",
                "layers",
                "measured_bottleneck",
                "predicted_bottleneck",
            ]
        );
        let layers = json.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), n);
        for l in layers {
            assert!(l.get("utilization").unwrap().as_f64().unwrap() > 0.0);
            let bound = l.get("bound").unwrap().as_str().unwrap();
            assert!(bound == "compute" || bound == "memory");
        }
    }
}
