//! Low-overhead continuous profiler: the per-stage *work ledger*.
//!
//! PR 5/8 gave every pipeline stage busy/stall wall-clock counters; this
//! module attributes *work* to that wall clock so the accounting layer
//! ([`crate::obs::account`]) can divide the two and get a rate.  The
//! ledger counts, per stage: input rows pushed, packed 64-bit words
//! XNOR'd, popcounts retired, and bytes moved (weights + input + output
//! activations).  All three are *derived constants of the layer geometry*
//! (paper eq. 9 nomenclature, [`crate::fpga::LayerGeom`]): the engine
//! does exactly `outputs * ceil(cnum/64)` packed-word ops per image per
//! layer, so the ledger increments once per flushed image by a
//! precomputed [`StageWork`] instead of instrumenting the kernel inner
//! loop — the hot path gains one relaxed load (disarmed) or three
//! relaxed `fetch_add`s per *image* (armed), never per word.
//!
//! Arming mirrors the tracing gate in [`crate::obs::ring`]: the
//! `BCNN_PROFILE` env var (default on; `off`/`0`/`false` disarm) seeds an
//! `AtomicU8`, and [`set_enabled`] flips it process-wide (the observer-
//! effect bench toggles it A/B).

use std::sync::atomic::{AtomicU8, Ordering};

use crate::fpga::{layer_geometry, LayerGeom};
use crate::model::NetConfig;

// Same shape as `ring::MODE`: an AtomicU8 whose relaxed load is the whole
// disarmed cost; first query resolves the env var.
const MODE_UNINIT: u8 = 0;
const MODE_OFF: u8 = 1;
const MODE_ON: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);

/// Is work-ledger accounting armed?  One relaxed load on the hot path.
#[inline]
pub fn enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        MODE_ON => true,
        MODE_OFF => false,
        _ => init_mode(),
    }
}

#[cold]
fn init_mode() -> bool {
    let on = match std::env::var("BCNN_PROFILE") {
        Ok(v) => !matches!(v.trim().to_ascii_lowercase().as_str(), "off" | "0" | "false"),
        Err(_) => true,
    };
    MODE.store(if on { MODE_ON } else { MODE_OFF }, Ordering::Relaxed);
    on
}

/// Arm or disarm the work ledger process-wide (the profile overhead bench
/// toggles this to measure the observer effect).
pub fn set_enabled(on: bool) {
    MODE.store(if on { MODE_ON } else { MODE_OFF }, Ordering::Relaxed);
}

/// Per-image work constants for one layer — what one flushed image adds
/// to its stage's ledger.  Derived from [`LayerGeom`] once at stage
/// startup, not measured: the tap-major engine's op count per image is a
/// pure function of geometry (eq. 9), so counting it at flush time is
/// exact, and free of inner-loop instrumentation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageWork {
    /// Input rows the stage consumes per image (`in_hw`; 1 for FC).
    pub rows: u64,
    /// Packed 64-bit words XNOR'd per image: `outputs * ceil(cnum/64)`.
    /// The fixed-point first layer runs MACs over the same geometry; its
    /// count is the packed-word *equivalent* of that work.
    pub xor_words: u64,
    /// Popcounts retired per image (one per XNOR'd word).
    pub popcounts: u64,
    /// Bytes moved per image: binary weights + input activations
    /// (integer `input_bits`-wide for the first layer, 1-bit packed
    /// elsewhere) + packed output activations.
    pub bytes_moved: u64,
}

impl StageWork {
    /// Derive the per-image ledger constants for `geom`.  `input_bits`
    /// only matters for the fixed-point first layer.
    pub fn for_layer(geom: &LayerGeom, input_bits: usize) -> StageWork {
        let words_per_output = geom.cnum.div_ceil(64) as u64;
        let xor_words = geom.outputs() * words_per_output;
        let weight_bytes = ((geom.dep * geom.cnum) as u64).div_ceil(8);
        let in_values = if geom.is_conv {
            // cnum = 9 * in_c for conv layers
            (geom.wid * geom.hei * (geom.cnum / 9)) as u64
        } else {
            geom.cnum as u64
        };
        let in_act_bytes = if geom.fixed_point {
            (in_values * input_bits as u64).div_ceil(8)
        } else {
            in_values.div_ceil(8)
        };
        let out_act_bytes = geom.outputs().div_ceil(8);
        StageWork {
            rows: if geom.is_conv { geom.hei as u64 } else { 1 },
            xor_words,
            popcounts: xor_words,
            bytes_moved: weight_bytes + in_act_bytes + out_act_bytes,
        }
    }

    /// Bit-operations per image: 64 XNORs + 64 popcount-accumulates per
    /// packed word — the roofline's work axis.
    pub fn bit_ops(&self) -> u64 {
        self.xor_words * 128
    }

    /// Arithmetic intensity in bit-ops per byte moved — the roofline's
    /// x-axis.  Compared against [`crate::obs::account::BALANCE_BIT_OPS_PER_BYTE`].
    pub fn intensity(&self) -> f64 {
        self.bit_ops() as f64 / (self.bytes_moved.max(1)) as f64
    }
}

/// The per-layer ledger constants for a whole network, index-aligned with
/// the pipeline's stages.
pub fn stage_work(config: &NetConfig) -> Vec<StageWork> {
    layer_geometry(config)
        .iter()
        .map(|g| StageWork::for_layer(g, config.input_bits))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_constants_match_eq9_geometry() {
        let cfg = NetConfig::table2();
        let geoms = layer_geometry(&cfg);
        let work = stage_work(&cfg);
        assert_eq!(work.len(), geoms.len());
        for (w, g) in work.iter().zip(&geoms) {
            // eq. 9: cycle_conv = outputs * cnum; the packed-word ledger
            // is the same work at 64 ops/word granularity
            assert_eq!(w.xor_words, g.outputs() * g.cnum.div_ceil(64) as u64);
            assert_eq!(w.popcounts, w.xor_words);
            assert!(w.bytes_moved > 0);
            assert_eq!(w.rows, if g.is_conv { g.hei as u64 } else { 1 });
        }
    }

    #[test]
    fn conv_layers_are_denser_than_fc() {
        // the roofline premise: conv reuses each weight byte across the
        // whole spatial plane, FC touches every weight byte exactly once
        let work = stage_work(&NetConfig::table2());
        let geoms = layer_geometry(&NetConfig::table2());
        let conv_min = work
            .iter()
            .zip(&geoms)
            .filter(|(_, g)| g.is_conv && !g.fixed_point)
            .map(|(w, _)| w.intensity())
            .fold(f64::INFINITY, f64::min);
        let fc_max = work
            .iter()
            .zip(&geoms)
            .filter(|(_, g)| !g.is_conv)
            .map(|(w, _)| w.intensity())
            .fold(0.0f64, f64::max);
        assert!(
            conv_min > fc_max,
            "conv intensity {conv_min:.1} must exceed FC intensity {fc_max:.1}"
        );
    }

    #[test]
    fn fc_intensity_sits_near_its_closed_form() {
        // FC: outputs = out_f, cnum = in_f, weights dominate bytes, so
        // intensity -> 128 * ceil(in_f/64) / (in_f/8) ~= 16 bit-ops/byte
        let cfg = NetConfig::table2();
        let work = stage_work(&cfg);
        let fc = &work[6]; // FC1: 8192 -> 1024
        assert!((fc.intensity() - 16.0).abs() < 1.0, "got {}", fc.intensity());
    }

    #[test]
    fn set_enabled_round_trips() {
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
    }
}
