//! Windowed telemetry: a ring of rolling `Metrics` deltas.
//!
//! The cumulative-since-start aggregates served by `STATS` answer "how
//! has this pool done over its lifetime" but not "what is p99 *right
//! now*" — during a hot-swap, a fault storm, or a traffic spike the
//! cumulative tail lags the live one by however much history it has
//! absorbed.  [`WindowTracker`] closes fixed-width windows (default
//! 1 s) over successive cumulative [`Metrics`] snapshots and keeps the
//! last N per-window deltas, so rate / p50 / p99 / error-rate /
//! crash-rate are queryable per window.  The serving registry folds the
//! result into `stats_json` under the `"windows"` key; `repro top`
//! renders it live.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::coordinator::Metrics;
use crate::util::json::Json;

/// Default window width: the classic 1-s telemetry tick.
pub const DEFAULT_WINDOW_INTERVAL: Duration = Duration::from_secs(1);

/// Default retention: two minutes of 1-s windows.
pub const DEFAULT_WINDOW_CAPACITY: usize = 120;

/// One closed window: the metrics delta accumulated between two ticks.
#[derive(Debug, Clone)]
pub struct WindowStat {
    /// Monotone window sequence number since tracker start (windows
    /// beyond the retention capacity are dropped, the numbering is not).
    pub index: u64,
    /// Window end, relative to tracker start.
    pub end_offset: Duration,
    /// Metrics accumulated in this window (`wall` = window interval, so
    /// `delta.throughput()` is the window's request rate).
    pub delta: Metrics,
}

impl WindowStat {
    /// Requests per second within the window.
    pub fn rate(&self) -> f64 {
        self.delta.throughput()
    }

    /// Error replies as a fraction of the window's requests (0 when idle).
    pub fn error_rate(&self) -> f64 {
        if self.delta.requests == 0 {
            0.0
        } else {
            self.delta.errors as f64 / self.delta.requests as f64
        }
    }

    /// Contained worker/stage crashes per second within the window.
    pub fn crash_rate(&self) -> f64 {
        let s = self.delta.wall.as_secs_f64();
        if s <= 0.0 {
            0.0
        } else {
            self.delta.crashes as f64 / s
        }
    }

    /// Flat JSON row (stable keys — pinned by the schema test).
    pub fn to_json(&self) -> Json {
        let us = |d: Duration| Json::Num(d.as_secs_f64() * 1e6);
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("index".into(), Json::Num(self.index as f64));
        m.insert("end_s".into(), Json::Num(self.end_offset.as_secs_f64()));
        m.insert("requests".into(), Json::Num(self.delta.requests as f64));
        m.insert("errors".into(), Json::Num(self.delta.errors as f64));
        m.insert("crashes".into(), Json::Num(self.delta.crashes as f64));
        m.insert("restarts".into(), Json::Num(self.delta.restarts as f64));
        m.insert(
            "requests_failed_over".into(),
            Json::Num(self.delta.requests_failed_over as f64),
        );
        m.insert("rate".into(), Json::Num(self.rate()));
        m.insert("error_rate".into(), Json::Num(self.error_rate()));
        m.insert("crash_rate".into(), Json::Num(self.crash_rate()));
        m.insert("latency_p50_us".into(), us(self.delta.p50()));
        m.insert("latency_p99_us".into(), us(self.delta.p99()));
        m.insert("latency_max_us".into(), us(self.delta.latency.max()));
        Json::Obj(m)
    }
}

/// Rolling-window tracker over cumulative [`Metrics`] snapshots.
///
/// Callers feed it `(now, cumulative)` pairs from any cadence (the admin
/// server ticks it from the accept loop's idle hook and before serving
/// `STATS`); it closes a window whenever `now` crosses the next boundary.
/// The delta since the previous snapshot is attributed to the *first*
/// window being closed — with ticks arriving much faster than the
/// interval that is exact; boundaries that elapsed while nobody ticked
/// close as explicitly empty windows rather than silently stretching.
#[derive(Debug)]
pub struct WindowTracker {
    interval: Duration,
    capacity: usize,
    started: Instant,
    next_boundary: Instant,
    last: Metrics,
    next_index: u64,
    windows: VecDeque<WindowStat>,
}

impl WindowTracker {
    pub fn new(interval: Duration, capacity: usize) -> Self {
        let started = Instant::now();
        WindowTracker {
            interval: interval.max(Duration::from_millis(1)),
            capacity: capacity.max(1),
            started,
            next_boundary: started + interval.max(Duration::from_millis(1)),
            last: Metrics::new(),
            next_index: 0,
            windows: VecDeque::new(),
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(DEFAULT_WINDOW_INTERVAL, DEFAULT_WINDOW_CAPACITY)
    }

    pub fn interval(&self) -> Duration {
        self.interval
    }

    /// Tracker epoch — window `end_offset`s are relative to this.
    pub fn started(&self) -> Instant {
        self.started
    }

    /// Cheap pre-check so idle-loop callers can skip the snapshot work
    /// (and the lock that guards it) between boundaries.
    pub fn due(&self, now: Instant) -> bool {
        now >= self.next_boundary
    }

    /// Close any windows whose boundary `now` has crossed.  Returns true
    /// if at least one window closed.
    pub fn tick(&mut self, now: Instant, cumulative: &Metrics) -> bool {
        if !self.due(now) {
            return false;
        }
        let mut delta = cumulative.delta_since(&self.last);
        delta.wall = self.interval;
        self.last = cumulative.clone();
        let end = self.next_boundary;
        self.next_boundary = end + self.interval;
        self.push(end, delta);
        while now >= self.next_boundary {
            // nobody ticked across these boundaries: close them empty
            let end = self.next_boundary;
            self.next_boundary = end + self.interval;
            let mut empty = Metrics::new();
            empty.wall = self.interval;
            self.push(end, empty);
        }
        true
    }

    fn push(&mut self, end: Instant, delta: Metrics) {
        let stat = WindowStat {
            index: self.next_index,
            end_offset: end.duration_since(self.started),
            delta,
        };
        self.next_index += 1;
        self.windows.push_back(stat);
        while self.windows.len() > self.capacity {
            self.windows.pop_front();
        }
    }

    /// Closed windows, oldest first.
    pub fn windows(&self) -> &VecDeque<WindowStat> {
        &self.windows
    }

    /// The most recently closed window.
    pub fn latest(&self) -> Option<&WindowStat> {
        self.windows.back()
    }

    /// JSON array of window rows, oldest first (the `"windows"` value in
    /// `stats_json`).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.windows.iter().map(WindowStat::to_json).collect())
    }
}

impl Default for WindowTracker {
    fn default() -> Self {
        Self::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cumulative(requests: usize, latency: Duration) -> Metrics {
        let mut m = Metrics::new();
        for _ in 0..requests {
            m.record_batch(1, latency / 2, None);
            m.record_request(latency / 2, latency);
        }
        m
    }

    #[test]
    fn closes_windows_with_deltas() {
        let mut t = WindowTracker::new(Duration::from_secs(1), 8);
        let start = t.started();
        assert!(!t.due(start));
        assert!(!t.tick(start, &Metrics::new()), "before the boundary: no window");

        let c1 = cumulative(10, Duration::from_millis(2));
        assert!(t.tick(start + Duration::from_secs(1), &c1));
        let mut c2 = cumulative(10, Duration::from_millis(2));
        c2.merge(&cumulative(5, Duration::from_millis(40)));
        assert!(t.tick(start + Duration::from_secs(2), &c2));

        let w = t.windows();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].delta.requests, 10);
        assert_eq!(w[1].delta.requests, 5, "second window sees only the delta");
        assert!((w[0].rate() - 10.0).abs() < 1e-9);
        assert!(w[0].delta.p99() <= Duration::from_millis(4));
        assert!(w[1].delta.p99() >= Duration::from_millis(30), "spike confined to its window");
        assert_eq!(w[1].index, 1);
        assert_eq!(w[1].end_offset, Duration::from_secs(2));
    }

    #[test]
    fn missed_boundaries_close_empty() {
        let mut t = WindowTracker::new(Duration::from_secs(1), 8);
        let start = t.started();
        let c = cumulative(6, Duration::from_millis(1));
        // one tick, three boundaries late: delta lands in the first
        // elapsed window, the other two close explicitly empty
        assert!(t.tick(start + Duration::from_millis(3_500), &c));
        let w = t.windows();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].delta.requests, 6);
        assert_eq!(w[1].delta.requests, 0);
        assert_eq!(w[2].delta.requests, 0);
        assert_eq!(w[2].error_rate(), 0.0);
        // next boundary is at 4 s: a tick at 3.9 s closes nothing
        assert!(!t.tick(start + Duration::from_millis(3_900), &c));
    }

    #[test]
    fn retention_drops_oldest_but_keeps_numbering() {
        let mut t = WindowTracker::new(Duration::from_secs(1), 3);
        let start = t.started();
        for i in 1..=5u64 {
            t.tick(start + Duration::from_secs(i), &Metrics::new());
        }
        let w = t.windows();
        assert_eq!(w.len(), 3);
        assert_eq!(w.front().unwrap().index, 2);
        assert_eq!(w.back().unwrap().index, 4);
    }

    #[test]
    fn window_json_rows_have_stable_keys() {
        let mut t = WindowTracker::new(Duration::from_secs(1), 4);
        let start = t.started();
        t.tick(start + Duration::from_secs(1), &cumulative(3, Duration::from_millis(2)));
        let j = t.to_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        let keys: Vec<&str> = match &rows[0] {
            Json::Obj(m) => m.keys().map(String::as_str).collect(),
            other => panic!("window row should be an object, got {other:?}"),
        };
        assert_eq!(
            keys,
            vec![
                "crash_rate",
                "crashes",
                "end_s",
                "error_rate",
                "errors",
                "index",
                "latency_max_us",
                "latency_p50_us",
                "latency_p99_us",
                "rate",
                "requests",
                "requests_failed_over",
                "restarts",
            ]
        );
        assert_eq!(rows[0].get("requests").unwrap().as_usize().unwrap(), 3);
    }
}
