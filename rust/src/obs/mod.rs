//! Observability: always-on request tracing and windowed telemetry.
//!
//! The paper's argument is an argument about *where time goes* — Fig. 7's
//! batch-(in)sensitivity, eq. 12's pipeline utilization, Tables 3–5's
//! per-stage occupancy.  This module is the host reproduction's
//! measurement substrate for the same question at serving time:
//!
//! * [`ring`] — per-shard / per-stage lock-free span ring buffers, trace
//!   IDs minted at admission and threaded end-to-end (coordinator →
//!   pipeline stages → protocol-v2 reply).
//! * [`export`] — Chrome trace-event JSON (`chrome://tracing` /
//!   Perfetto), one track per shard and per stage, served over the
//!   `OP_TRACE` admin frame and `repro trace`.
//! * [`window`] — rolling per-window `Metrics` deltas (rate, p50/p99,
//!   error/crash rate per window), folded into `stats_json` under
//!   `"windows"` and rendered live by `repro top`.
//! * [`profile`] — the per-stage work ledger (rows, packed words XNOR'd,
//!   popcounts, bytes moved), incremented once per flushed image from
//!   geometry-derived constants behind its own `BCNN_PROFILE` gate.
//! * [`account`] — performance accounting: reconciles the ledger +
//!   busy/stall counters against `fpga::timing`'s eqs. 9–12 into
//!   per-stage utilization, roofline bound classes, and a measured-vs-
//!   predicted bottleneck verdict (`OP_PROFILE`, `repro profile`).
//!
//! Everything is std-only and wait-free on the hot path: with tracing
//! disarmed a span site costs one relaxed atomic load; armed, one
//! clock read and a handful of relaxed stores into a fixed ring.

pub mod account;
pub mod export;
pub mod profile;
pub mod ring;
pub mod window;

pub use account::{
    classify, reconcile, reconcile_at, utilization, AccountReport, Bound, LayerAccount,
    BALANCE_BIT_OPS_PER_BYTE,
};
pub use export::{chrome_trace_for, chrome_trace_json};
pub use profile::{
    enabled as profile_enabled, set_enabled as set_profile_enabled, stage_work, StageWork,
};
pub use ring::{
    enabled, mint_trace_id, next_instance_id, now_ns, rings, set_enabled, SpanEvent, SpanKind,
    SpanRing, StageTracer, TraceLog, DEFAULT_RING_CAPACITY,
};
pub use window::{WindowStat, WindowTracker, DEFAULT_WINDOW_CAPACITY, DEFAULT_WINDOW_INTERVAL};
