//! Reproduction of *"A GPU-Outperforming FPGA Accelerator Architecture for
//! Binary Convolutional Neural Networks"* (Li, Liu, Xu, Yu, Ren — 2017).
//!
//! Three-layer architecture (DESIGN.md):
//!
//! * **L1/L2** live in `python/compile/`: Pallas XNOR-GEMM kernels and the
//!   JAX BCNN forward graph, AOT-lowered once to HLO text artifacts.
//! * **L3** is this crate: the serving coordinator ([`coordinator`]), the
//!   PJRT runtime that executes the AOT artifacts ([`runtime`]), the native
//!   packed-`u64` inference engine ([`bcnn`]) used as the hot path and as
//!   the functional model of the FPGA datapath, the row-streaming
//!   layer-pipeline runtime ([`pipeline`]) that executes the paper's
//!   all-layers-concurrent dataflow for real on host threads, and the
//!   paper's architecture itself as a simulator: [`fpga`]
//!   (timing/resource/power), [`optimizer`] (the §4.3
//!   throughput-balancing model, Table 3) and [`gpu`] (the Titan X
//!   comparator of Fig. 7).
//! * **L4** is the serving control plane ([`serving`]): a multi-model
//!   registry (one coordinator pool per named, versioned model),
//!   zero-downtime hot-swap via an epoch-tagged routing-table swap, and
//!   protocol v2 — model-routed request frames plus
//!   `DEPLOY`/`UNDEPLOY`/`ROLLBACK`/`LIST`/`STATS` admin frames.
//! * Cross-cutting: [`obs`] — always-on span tracing (per-shard /
//!   per-stage rings, trace IDs minted at admission, Chrome-trace
//!   export via `OP_TRACE`) and windowed telemetry behind `STATS`'
//!   `"windows"` key and the `repro top` dashboard.
//!
//! Python never runs at request time: the `repro` binary is self-contained
//! once `make artifacts` has produced `artifacts/*.hlo.txt` + `*.bcnn`.

pub mod bcnn;
pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod fpga;
pub mod gpu;
pub mod model;
pub mod obs;
pub mod optimizer;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod tables;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
