//! `repro` — leader entrypoint for the BCNN FPGA-accelerator reproduction.
//!
//! Python never runs here: the binary loads AOT artifacts produced once by
//! `make artifacts` (HLO text + `.bcnn` weights) and serves/simulates from
//! rust alone.  See `repro help` for the subcommands.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = repro::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
