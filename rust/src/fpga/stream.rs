//! Phase-level streaming-system simulator (paper §4.3, fig. 4).
//!
//! All layers run concurrently; double-buffered channels decouple them; a
//! phase ends when every active layer has finished its feature map, so the
//! phase length is `max_L(C_L)` — exactly eq. 12.  The simulator moves
//! *real activations* through [`DoubleBuffer`] channels and computes them
//! with the bit-exact engine, so it validates both the schedule (cycle
//! accounting, buffer discipline) and the numerics (scores must equal
//! plain `Engine::infer`).
//!
//! The batch-insensitivity headline of Fig. 7 falls out of this schedule:
//! one image leaves the pipeline per phase regardless of how many are
//! queued.

use anyhow::{anyhow, bail, Result};

use crate::bcnn::engine::Scratch;
use crate::bcnn::tensor::Activation;
use crate::bcnn::{Engine, LayerOutput};
use crate::fpga::channel::DoubleBuffer;
use crate::fpga::timing::{cycle_real, LayerParams, PipelineModel};
use crate::fpga::{layer_geometry, LayerGeom};

/// System configuration for a simulation run.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    pub freq_hz: f64,
    pub params: Vec<LayerParams>,
    pub pipeline: PipelineModel,
    /// Disable double buffering (ablation): layers run sequentially per
    /// image, so the phase length becomes `sum(C_L)` instead of `max(C_L)`.
    pub double_buffered: bool,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// Per-layer modeled cycles (`Cycle_r`).
    pub layer_cycles: Vec<u64>,
    /// Cycles of the steady-state phase (`max` or `sum` per configuration).
    pub phase_cycles: u64,
    /// Total cycles for the whole batch (pipeline fill + drain included).
    pub total_cycles: u64,
    /// Per-image completion times in cycles since t=0.
    pub completion_cycles: Vec<u64>,
    /// Steady-state throughput at `freq_hz`.
    pub fps: f64,
    /// First-image latency in seconds.
    pub first_latency_s: f64,
    /// Per-layer utilization within a steady phase (C_L / phase).
    pub utilization: Vec<f64>,
    /// Classifier scores per image (bit-exact vs `Engine::infer`).
    pub scores: Vec<Vec<f32>>,
}

/// Simulate the streaming accelerator over a batch of images (owned or
/// borrowed rows — the serving path lends request buffers zero-copy).
pub fn simulate<I: AsRef<[i32]>>(
    engine: &Engine,
    config: &StreamConfig,
    images: &[I],
) -> Result<StreamReport> {
    let model = engine.model();
    let geoms = layer_geometry(&model.config());
    let n_layers = model.layers.len();
    if config.params.len() != n_layers {
        bail!("need {} layer params, got {}", n_layers, config.params.len());
    }
    let layer_cycles: Vec<u64> = geoms
        .iter()
        .zip(&config.params)
        .map(|(g, p)| cycle_real(g, p, &config.pipeline))
        .collect();

    if !config.double_buffered {
        return simulate_sequential(engine, config, images, &geoms, &layer_cycles);
    }

    let phase_cycles = *layer_cycles.iter().max().ok_or_else(|| anyhow!("no layers"))?;
    let n = images.len();
    // channels[l] connects layer l-1 -> layer l; channels[0] is the input
    // feed, channels[n_layers] collects scores.
    let mut channels: Vec<DoubleBuffer<Activation>> =
        (0..n_layers).map(|_| DoubleBuffer::new()).collect();
    let mut out_scores: Vec<Vec<f32>> = Vec::with_capacity(n);
    let mut completion_cycles = Vec::with_capacity(n);
    let mut clock: u64 = 0;
    let mut fed = 0usize;
    let mut scratch = Scratch::default();

    // Each iteration is one phase.  Feed one image per phase (the host
    // interface keeps up: one image per max(C_L) cycles).
    while out_scores.len() < n {
        let mut active = false;
        // layers run "concurrently": all read their front buffers as they
        // were at phase start.  Process back-to-front so writes land in
        // back slots without ordering artifacts.
        for l in (0..n_layers).rev() {
            let input = channels[l].read();
            if let Some(act) = input {
                active = true;
                match engine.run_layer_at(l, &act, &mut scratch)? {
                    LayerOutput::Act(next) => {
                        if l + 1 < n_layers {
                            channels[l + 1]
                                .write(next)
                                .map_err(|e| anyhow!("layer {}: {e}", l + 1))?;
                        } else {
                            bail!("non-classifier output from last layer");
                        }
                    }
                    LayerOutput::Scores(s) => {
                        if l + 1 != n_layers {
                            bail!("classifier layer {l} is not last");
                        }
                        out_scores.push(s);
                        completion_cycles.push(clock + phase_cycles);
                    }
                }
            }
        }
        // host feeds the next image into layer 0's channel
        if fed < n {
            let hw = model.input_hw;
            let c = model.input_channels;
            channels[0]
                .write(Activation::Int { hw, c, data: images[fed].as_ref().to_vec() })
                .map_err(|e| anyhow!("input channel: {e}"))?;
            fed += 1;
            active = true;
        }
        if !active {
            bail!("deadlock: no layer active but {} images missing", n - out_scores.len());
        }
        for ch in &mut channels {
            ch.swap();
        }
        clock += phase_cycles;
    }

    let utilization = layer_cycles.iter().map(|&c| c as f64 / phase_cycles as f64).collect();
    Ok(StreamReport {
        fps: config.freq_hz / phase_cycles as f64,
        first_latency_s: completion_cycles.first().map(|&c| c as f64 / config.freq_hz).unwrap_or(0.0),
        layer_cycles,
        phase_cycles,
        total_cycles: clock,
        completion_cycles,
        utilization,
        scores: out_scores,
    })
}

/// Ablation mode: no double buffering — one image occupies the whole
/// datapath; layers execute in sequence (the time-multiplexed scheme the
/// paper criticizes in Ref. 21, §6.2).
fn simulate_sequential<I: AsRef<[i32]>>(
    engine: &Engine,
    config: &StreamConfig,
    images: &[I],
    _geoms: &[LayerGeom],
    layer_cycles: &[u64],
) -> Result<StreamReport> {
    let per_image: u64 = layer_cycles.iter().sum();
    let mut scores = Vec::with_capacity(images.len());
    let mut completion_cycles = Vec::with_capacity(images.len());
    let mut clock = 0u64;
    for img in images {
        scores.push(engine.infer(img.as_ref())?);
        clock += per_image;
        completion_cycles.push(clock);
    }
    Ok(StreamReport {
        layer_cycles: layer_cycles.to_vec(),
        phase_cycles: per_image,
        total_cycles: clock,
        completion_cycles,
        fps: config.freq_hz / per_image as f64,
        first_latency_s: per_image as f64 / config.freq_hz,
        utilization: layer_cycles.iter().map(|&c| c as f64 / per_image as f64).collect(),
        scores,
    })
}
