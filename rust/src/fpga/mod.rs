//! The paper's accelerator architecture as a simulator.
//!
//! The physical Virtex-7 + Vivado HLS flow is hardware-gated, so this
//! module reproduces the *architecture* (paper §4–5) as executable models:
//!
//! * [`timing`] — the throughput model of eqs. 9–12 plus a microarchitecture
//!   cycle model (pipeline fill, row control) approximating `Cycle_r`;
//! * [`pe`] — the PE of fig. 5 (UF-wide XNOR array + popcount tree),
//!   functional + per-stage latency;
//! * [`kernel`] — the computing kernel of fig. 6 (P-wide PE array with
//!   accumulators, fused MP + NB);
//! * [`channel`] — the double-buffered inter-layer memory channels (§4.3);
//! * [`stream`] — the phase-level system simulator implementing eq. 12's
//!   streaming semantics with bit-exact numerics (it runs the real network
//!   through [`crate::bcnn::Engine`] layer by layer);
//! * [`memory`] — BRAM banking (§5.3: reshape by 32, partition for
//!   bandwidth);
//! * [`resource`] — the Table 4 utilization model;
//! * [`power`] — the Table 5 power/energy model.
//!
//! Model constants calibrated against the paper's reported implementation
//! are marked `CAL:` at their definition sites and collected in
//! DESIGN.md §2.

pub mod channel;
pub mod kernel;
pub mod memory;
pub mod pe;
pub mod power;
pub mod resource;
pub mod stream;
pub mod timing;

use crate::model::NetConfig;

/// Paper-default system clock (§6.2: 90 MHz on the XC7VX690).
pub const DEFAULT_FREQ_HZ: f64 = 90.0e6;

/// Geometry of one layer as the throughput model sees it (paper eq. 9
/// nomenclature): the convolution output is `wid x hei x dep` at *conv*
/// resolution (pre-pool), each output value costing `cnum` XNOR ops.
/// FC layers are `1 x 1 x out_f` with `cnum = in_f`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerGeom {
    /// 1-based layer index (paper numbering).
    pub index: usize,
    pub name: String,
    pub is_conv: bool,
    pub wid: usize,
    pub hei: usize,
    pub dep: usize,
    pub cnum: usize,
    pub pool: bool,
    /// First layer runs fixed-point MACs on DSPs instead of XNOR LUTs.
    pub fixed_point: bool,
}

impl LayerGeom {
    /// Output values computed per feature map.
    pub fn outputs(&self) -> u64 {
        (self.wid * self.hei * self.dep) as u64
    }
}

/// Resolve a network into per-layer geometry (paper Table 2 -> Table 3
/// rows).
pub fn layer_geometry(config: &NetConfig) -> Vec<LayerGeom> {
    let mut geoms = Vec::new();
    for (i, s) in config.conv_shapes().iter().enumerate() {
        geoms.push(LayerGeom {
            index: i + 1,
            name: format!("Conv {}", i + 1),
            is_conv: true,
            wid: s.in_hw,
            hei: s.in_hw,
            dep: s.out_c,
            cnum: 9 * s.in_c,
            pool: s.pool,
            fixed_point: i == 0,
        });
    }
    let n_conv = config.conv.len();
    for (j, (in_f, out_f)) in config.fc_shapes().iter().enumerate() {
        geoms.push(LayerGeom {
            index: n_conv + 1 + j,
            name: format!("FC {}", j + 1),
            is_conv: false,
            wid: 1,
            hei: 1,
            dep: *out_f,
            cnum: *in_f,
            pool: false,
            fixed_point: false,
        });
    }
    geoms
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_geometry_matches_table3_cycle_conv() {
        // paper Table 3 Cycle_conv column
        let geoms = layer_geometry(&NetConfig::table2());
        let cycle_conv: Vec<u64> = geoms.iter().map(|g| g.outputs() * g.cnum as u64).collect();
        assert_eq!(
            &cycle_conv[..6],
            &[3_538_944, 150_994_944, 75_497_472, 150_994_944, 75_497_472, 150_994_944]
        );
    }

    #[test]
    fn fc_geometry() {
        let geoms = layer_geometry(&NetConfig::table2());
        assert_eq!(geoms.len(), 9);
        assert_eq!(geoms[6].cnum, 8192);
        assert_eq!(geoms[6].dep, 1024);
        assert!(!geoms[6].is_conv);
    }
}
