//! Power and energy model — the Table 5 "Ours" row (8.2 W, 935 GOPS/W).
//!
//! First-order FPGA power: static leakage plus dynamic CV²f over the
//! toggling fabric.  Coefficients (CAL) are set so the Table-3/Table-4
//! design point reproduces the paper's 8.2 W implementation report; the
//! *scaling* (with utilization, clock, and toggle activity) is physical,
//! so ablation benches can vary the design point meaningfully.

use crate::fpga::resource::ResourceReport;

/// CAL: XC7VX690 static power at nominal voltage/temp (Xilinx XPE-class
/// estimate for this device family).
pub const STATIC_W: f64 = 2.4;
/// CAL: dynamic watts per (kLUT * GHz) at the datapath's toggle activity.
/// Register toggling is folded in (registers share the slices).
pub const W_PER_KLUT_GHZ: f64 = 0.152;
/// CAL: dynamic watts per (1000 BRAM * GHz) — 36Kb blocks, ports active.
pub const W_PER_KBRAM_GHZ: f64 = 9.0e3;
/// CAL: dynamic watts per (1000 DSP48 * GHz).
pub const W_PER_KDSP_GHZ: f64 = 3.0e3;

/// Power breakdown at a design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerReport {
    pub static_w: f64,
    pub lut_w: f64,
    pub bram_w: f64,
    pub dsp_w: f64,
}

impl PowerReport {
    pub fn total_w(&self) -> f64 {
        self.static_w + self.lut_w + self.bram_w + self.dsp_w
    }
}

/// Estimate total board power for a resource report at `freq_hz`.
pub fn power(resources: &ResourceReport, freq_hz: f64) -> PowerReport {
    let ghz = freq_hz / 1e9;
    PowerReport {
        static_w: STATIC_W,
        lut_w: resources.total.luts as f64 / 1000.0 * W_PER_KLUT_GHZ * ghz,
        bram_w: resources.total.brams as f64 / 1000.0 * W_PER_KBRAM_GHZ * ghz / 1000.0,
        dsp_w: resources.total.dsps as f64 / 1000.0 * W_PER_KDSP_GHZ * ghz / 1000.0,
    }
}

/// Energy per image in joules at a given throughput.
pub fn energy_per_image_j(power_w: f64, fps: f64) -> f64 {
    if fps <= 0.0 {
        return f64::INFINITY;
    }
    power_w / fps
}

/// GOPS/W — Table 5's energy-efficiency metric.
pub fn gops_per_w(gops: f64, power_w: f64) -> f64 {
    gops / power_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resource::{report, VIRTEX7_690T};
    use crate::fpga::timing::{paper_fc_params, paper_table3_conv_params};
    use crate::fpga::{layer_geometry, DEFAULT_FREQ_HZ};
    use crate::model::NetConfig;

    fn table2_power() -> PowerReport {
        let geoms = layer_geometry(&NetConfig::table2());
        let mut params = paper_table3_conv_params();
        for g in &geoms[6..] {
            params.push(paper_fc_params(g));
        }
        power(&report(&geoms, &params, VIRTEX7_690T), DEFAULT_FREQ_HZ)
    }

    #[test]
    fn table5_power_within_band() {
        // paper: 8.2 W at 90 MHz
        let p = table2_power().total_w();
        let err = (p - 8.2).abs() / 8.2;
        assert!(err < 0.15, "power {p:.2} W vs 8.2 W ({:.1}% off)", err * 100.0);
    }

    #[test]
    fn power_scales_with_clock() {
        let geoms = layer_geometry(&NetConfig::table2());
        let mut params = paper_table3_conv_params();
        for g in &geoms[6..] {
            params.push(paper_fc_params(g));
        }
        let r = report(&geoms, &params, VIRTEX7_690T);
        let p90 = power(&r, 90e6).total_w();
        let p180 = power(&r, 180e6).total_w();
        assert!(p180 > p90);
        // dynamic part doubles, static does not
        assert!((p180 - STATIC_W) / (p90 - STATIC_W) > 1.9);
    }

    #[test]
    fn energy_metrics() {
        assert!((energy_per_image_j(8.2, 6218.0) - 0.0013187).abs() < 1e-5);
        assert!((gops_per_w(7663.0, 8.2) - 934.5).abs() < 1.0);
        assert!(energy_per_image_j(8.2, 0.0).is_infinite());
    }
}
