//! The paper's throughput model (eqs. 9–12) plus a microarchitecture
//! cycle model for `Cycle_r`.
//!
//! * eq. 9:  `Cycle_conv = WID*HEI*DEP * FW*FH*FD`
//! * eq. 11: `Cycle_est  = Cycle_conv / (UF*P) * I`
//! * eq. 12: system throughput = `freq / max_L(C_L)` (double-buffered
//!   streaming: every layer runs each phase; the slowest layer sets the
//!   phase length)
//!
//! `Cycle_r` (the Vivado-HLS-measured column of Table 3) exceeds
//! `Cycle_est` by pipeline fill and loop control.  We model the HLS loop
//! structure the paper describes (§4.2: inner dot-product loop unrolled by
//! UF, pipelined II=1 across output positions, flushed at each feature-map
//! row): per output row, `trips + depth - 1 + row_ctrl` cycles, where
//! `depth` is the XNOR -> popcount-tree -> accumulate -> MP/NB pipeline
//! depth.  Residual deviation from the paper's exact numbers is unmodeled
//! HLS control overhead; EXPERIMENTS.md reports both side by side.

use std::fmt;

use super::LayerGeom;

/// Why a timing query could not be answered — typed, so report builders
/// surface the misuse instead of folding a silent `0.0` into a table.
/// The panicking/zero-returning plain functions keep their documented
/// behavior; the `try_*` variants return these.
#[derive(Debug, Clone, PartialEq)]
pub enum TimingError {
    /// `UF * P == 0`: eq. 11's denominator vanishes (the plain
    /// [`cycle_est`] panics on the division).
    ZeroLanes,
    /// An empty per-layer cycle slice: no pipeline to take a bottleneck
    /// over (the plain [`system_fps`] / [`pipeline_latency_s`] return
    /// `0.0` by documented convention).
    EmptyPipeline,
    /// The reference clock is zero, negative, or non-finite.
    BadClock(f64),
}

impl fmt::Display for TimingError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TimingError::ZeroLanes => write!(f, "layer params have UF*P == 0 lanes"),
            TimingError::EmptyPipeline => write!(f, "empty per-layer cycle slice"),
            TimingError::BadClock(hz) => write!(f, "clock must be positive and finite, got {hz}"),
        }
    }
}

impl std::error::Error for TimingError {}

/// Architectural parameters of one layer (paper Table 3 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerParams {
    /// Unfolding factor: XNOR lanes per PE (temporal parallelism, §4.2.1).
    pub uf: usize,
    /// PE count: output values computed in parallel (spatial parallelism).
    pub p: usize,
    /// Pipeline initiation interval (paper achieves II=1 on every layer).
    pub ii: usize,
}

impl LayerParams {
    pub fn new(uf: usize, p: usize) -> Self {
        Self { uf, p, ii: 1 }
    }

    /// Total XNOR lanes this layer instantiates.
    pub fn lanes(&self) -> u64 {
        (self.uf * self.p) as u64
    }
}

/// Microarchitecture constants for the `Cycle_r` model.
#[derive(Debug, Clone, Copy)]
pub struct PipelineModel {
    /// Pipeline stages beyond the popcount tree (XNOR, accumulate, MP/NB
    /// write-back).  CAL: 4 stages, consistent with the paper's "deep
    /// pipeline" fig. 5/6 datapath.
    pub base_stages: u64,
    /// Control cycles per feature-map row (HLS loop enter/exit).
    pub row_ctrl: u64,
    /// Fixed per-layer control (buffer swap handshake).
    pub layer_ctrl: u64,
}

impl Default for PipelineModel {
    fn default() -> Self {
        Self { base_stages: 4, row_ctrl: 2, layer_ctrl: 16 }
    }
}

/// Paper Table 3 parameters (UF, P) for the six Table-2 conv layers.
pub fn paper_table3_conv_params() -> Vec<LayerParams> {
    vec![
        LayerParams::new(27, 32),
        LayerParams::new(384, 32),
        LayerParams::new(384, 16),
        LayerParams::new(768, 16),
        LayerParams::new(768, 8),
        LayerParams::new(1536, 8),
    ]
}

/// FC-layer parameters matching the paper's design principle (§4.3: FC
/// layers "easily optimized to match up the system throughput"): UF = the
/// full input width capped at 1024 bits of BRAM bandwidth, P sized so
/// Cycle_est stays under the conv bottleneck (12288).
pub fn paper_fc_params(geom: &LayerGeom) -> LayerParams {
    let uf = geom.cnum.min(1024);
    let trips = (geom.cnum as u64).div_ceil(uf as u64);
    let target = 12_288u64;
    let p = ((geom.dep as u64 * trips).div_ceil(target)).next_power_of_two() as usize;
    LayerParams::new(uf, p.max(1))
}

/// eq. 9 — total sequential XNOR-accumulate cycles of a layer.
pub fn cycle_conv(geom: &LayerGeom) -> u64 {
    geom.outputs() * geom.cnum as u64
}

/// eq. 11 — estimated cycles with unfolding UF, parallelism P, interval I.
/// Panics on zero-lane params (division by `UF*P`); use [`try_cycle_est`]
/// where the params come from outside the paper tables.
pub fn cycle_est(geom: &LayerGeom, params: &LayerParams) -> u64 {
    let denom = params.lanes();
    (cycle_conv(geom)).div_ceil(denom) * params.ii as u64
}

/// [`cycle_est`] with the zero-lane boundary surfaced as a typed error
/// instead of a panic.
pub fn try_cycle_est(geom: &LayerGeom, params: &LayerParams) -> Result<u64, TimingError> {
    if params.lanes() == 0 {
        return Err(TimingError::ZeroLanes);
    }
    Ok(cycle_est(geom, params))
}

/// Microarchitecture model of the Vivado-HLS-measured `Cycle_r`.
pub fn cycle_real(geom: &LayerGeom, params: &LayerParams, model: &PipelineModel) -> u64 {
    let rows = geom.hei as u64;
    // output positions per row, processed P at a time, each needing
    // cnum/UF pipelined trips
    let groups_per_row = ((geom.wid * geom.dep) as u64).div_ceil(params.p as u64);
    let trips_per_group = (geom.cnum as u64).div_ceil(params.uf as u64);
    let trips_row = groups_per_row * trips_per_group * params.ii as u64;
    let depth = (params.uf.max(2) as f64).log2().ceil() as u64 + model.base_stages;
    rows * (trips_row + depth - 1 + model.row_ctrl) + model.layer_ctrl
}

/// eq. 12 — steady-state system FPS given per-layer cycles and the clock.
/// Documented zero convention: an empty slice (or all-zero cycles) is "no
/// pipeline" and returns `0.0` FPS; use [`try_system_fps`] where an empty
/// slice indicates caller misuse that should not fold into a report.
pub fn system_fps(per_layer_cycles: &[u64], freq_hz: f64) -> f64 {
    let bottleneck = per_layer_cycles.iter().copied().max().unwrap_or(0);
    if bottleneck == 0 {
        return 0.0;
    }
    freq_hz / bottleneck as f64
}

/// [`system_fps`] with the boundaries surfaced as typed errors: empty
/// slices and bad clocks error instead of contributing `0.0`/NaN rows.
pub fn try_system_fps(per_layer_cycles: &[u64], freq_hz: f64) -> Result<f64, TimingError> {
    if per_layer_cycles.is_empty() {
        return Err(TimingError::EmptyPipeline);
    }
    if !(freq_hz.is_finite() && freq_hz > 0.0) {
        return Err(TimingError::BadClock(freq_hz));
    }
    Ok(system_fps(per_layer_cycles, freq_hz))
}

/// Single-image pipeline latency: with double-buffered phases every image
/// traverses `L` phases of the bottleneck length (§4.3).  Documented zero
/// convention: an empty slice is "no pipeline" and returns `0.0`; see
/// [`try_pipeline_latency_s`].
pub fn pipeline_latency_s(per_layer_cycles: &[u64], freq_hz: f64) -> f64 {
    let bottleneck = per_layer_cycles.iter().copied().max().unwrap_or(0) as f64;
    per_layer_cycles.len() as f64 * bottleneck / freq_hz
}

/// [`pipeline_latency_s`] with typed boundary errors (empty pipeline, bad
/// clock) instead of silent zeros.
pub fn try_pipeline_latency_s(per_layer_cycles: &[u64], freq_hz: f64) -> Result<f64, TimingError> {
    if per_layer_cycles.is_empty() {
        return Err(TimingError::EmptyPipeline);
    }
    if !(freq_hz.is_finite() && freq_hz > 0.0) {
        return Err(TimingError::BadClock(freq_hz));
    }
    Ok(pipeline_latency_s(per_layer_cycles, freq_hz))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::layer_geometry;
    use crate::model::NetConfig;

    fn paper_conv_params() -> Vec<LayerParams> {
        paper_table3_conv_params()
    }

    #[test]
    fn table3_cycle_est_exact() {
        let geoms = layer_geometry(&NetConfig::table2());
        let params = paper_conv_params();
        let est: Vec<u64> = geoms[..6]
            .iter()
            .zip(&params)
            .map(|(g, p)| cycle_est(g, p))
            .collect();
        assert_eq!(est, vec![4096, 12288, 12288, 12288, 12288, 12288]);
    }

    #[test]
    fn cycle_real_close_to_paper() {
        // paper Table 3 Cycle_r: 5233, 12386, 12296, 13329, 12386, 14473.
        // our microarchitecture model must land within 20% per layer and
        // within 20% on the bottleneck.
        let paper_r = [5233u64, 12386, 12296, 13329, 12386, 14473];
        let geoms = layer_geometry(&NetConfig::table2());
        let params = paper_conv_params();
        let model = PipelineModel::default();
        for ((g, p), &want) in geoms[..6].iter().zip(&params).zip(&paper_r) {
            let got = cycle_real(g, p, &model);
            let err = (got as f64 - want as f64).abs() / want as f64;
            assert!(err < 0.25, "{}: got {got}, paper {want} ({:.1}% off)", g.name, err * 100.0);
            assert!(got >= cycle_est(g, p), "real >= est");
        }
    }

    #[test]
    fn fps_headline_shape() {
        // paper §6.2: 6218 FPS at 90 MHz (bottleneck 14473 cycles).  Our
        // model's bottleneck must give the same order: within 25%.
        let geoms = layer_geometry(&NetConfig::table2());
        let params = paper_conv_params();
        let model = PipelineModel::default();
        let cycles: Vec<u64> = geoms[..6]
            .iter()
            .zip(&params)
            .map(|(g, p)| cycle_real(g, p, &model))
            .collect();
        let fps = system_fps(&cycles, 90.0e6);
        assert!((fps - 6218.0).abs() / 6218.0 < 0.25, "fps {fps}");
    }

    #[test]
    fn est_divides_exactly_for_paper_params() {
        // UF*P divides Cycle_conv for every Table 3 row
        let geoms = layer_geometry(&NetConfig::table2());
        for (g, p) in geoms[..6].iter().zip(paper_conv_params()) {
            assert_eq!(cycle_conv(g) % p.lanes(), 0, "{}", g.name);
        }
    }

    #[test]
    fn system_fps_empty_is_zero() {
        assert_eq!(system_fps(&[], 90e6), 0.0);
    }

    #[test]
    fn try_variants_type_the_boundaries() {
        let geoms = layer_geometry(&NetConfig::tiny());
        let g = &geoms[0];
        // zero lanes: plain cycle_est would panic on the division
        let zero = LayerParams { uf: 0, p: 0, ii: 1 };
        assert_eq!(try_cycle_est(g, &zero), Err(TimingError::ZeroLanes));
        let one = LayerParams::new(1, 1);
        assert_eq!(try_cycle_est(g, &one), Ok(cycle_est(g, &one)));

        assert_eq!(try_system_fps(&[], 90e6), Err(TimingError::EmptyPipeline));
        assert_eq!(try_pipeline_latency_s(&[], 90e6), Err(TimingError::EmptyPipeline));
        assert_eq!(try_system_fps(&[100], 0.0), Err(TimingError::BadClock(0.0)));
        assert!(matches!(
            try_system_fps(&[100], f64::NAN),
            Err(TimingError::BadClock(hz)) if hz.is_nan()
        ));
        assert_eq!(
            try_pipeline_latency_s(&[100], -1.0),
            Err(TimingError::BadClock(-1.0))
        );
        assert_eq!(try_system_fps(&[9_000], 90e6), Ok(10_000.0));
        let lat = try_pipeline_latency_s(&[100, 200], 200.0).unwrap();
        assert!((lat - 2.0).abs() < 1e-12); // 2 layers x 200-cycle phase / 200 Hz
    }

    #[test]
    fn latency_empty_is_zero() {
        assert_eq!(pipeline_latency_s(&[], 90e6), 0.0);
    }
}
