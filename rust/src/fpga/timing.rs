//! The paper's throughput model (eqs. 9–12) plus a microarchitecture
//! cycle model for `Cycle_r`.
//!
//! * eq. 9:  `Cycle_conv = WID*HEI*DEP * FW*FH*FD`
//! * eq. 11: `Cycle_est  = Cycle_conv / (UF*P) * I`
//! * eq. 12: system throughput = `freq / max_L(C_L)` (double-buffered
//!   streaming: every layer runs each phase; the slowest layer sets the
//!   phase length)
//!
//! `Cycle_r` (the Vivado-HLS-measured column of Table 3) exceeds
//! `Cycle_est` by pipeline fill and loop control.  We model the HLS loop
//! structure the paper describes (§4.2: inner dot-product loop unrolled by
//! UF, pipelined II=1 across output positions, flushed at each feature-map
//! row): per output row, `trips + depth - 1 + row_ctrl` cycles, where
//! `depth` is the XNOR -> popcount-tree -> accumulate -> MP/NB pipeline
//! depth.  Residual deviation from the paper's exact numbers is unmodeled
//! HLS control overhead; EXPERIMENTS.md reports both side by side.

use super::LayerGeom;

/// Architectural parameters of one layer (paper Table 3 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerParams {
    /// Unfolding factor: XNOR lanes per PE (temporal parallelism, §4.2.1).
    pub uf: usize,
    /// PE count: output values computed in parallel (spatial parallelism).
    pub p: usize,
    /// Pipeline initiation interval (paper achieves II=1 on every layer).
    pub ii: usize,
}

impl LayerParams {
    pub fn new(uf: usize, p: usize) -> Self {
        Self { uf, p, ii: 1 }
    }

    /// Total XNOR lanes this layer instantiates.
    pub fn lanes(&self) -> u64 {
        (self.uf * self.p) as u64
    }
}

/// Microarchitecture constants for the `Cycle_r` model.
#[derive(Debug, Clone, Copy)]
pub struct PipelineModel {
    /// Pipeline stages beyond the popcount tree (XNOR, accumulate, MP/NB
    /// write-back).  CAL: 4 stages, consistent with the paper's "deep
    /// pipeline" fig. 5/6 datapath.
    pub base_stages: u64,
    /// Control cycles per feature-map row (HLS loop enter/exit).
    pub row_ctrl: u64,
    /// Fixed per-layer control (buffer swap handshake).
    pub layer_ctrl: u64,
}

impl Default for PipelineModel {
    fn default() -> Self {
        Self { base_stages: 4, row_ctrl: 2, layer_ctrl: 16 }
    }
}

/// Paper Table 3 parameters (UF, P) for the six Table-2 conv layers.
pub fn paper_table3_conv_params() -> Vec<LayerParams> {
    vec![
        LayerParams::new(27, 32),
        LayerParams::new(384, 32),
        LayerParams::new(384, 16),
        LayerParams::new(768, 16),
        LayerParams::new(768, 8),
        LayerParams::new(1536, 8),
    ]
}

/// FC-layer parameters matching the paper's design principle (§4.3: FC
/// layers "easily optimized to match up the system throughput"): UF = the
/// full input width capped at 1024 bits of BRAM bandwidth, P sized so
/// Cycle_est stays under the conv bottleneck (12288).
pub fn paper_fc_params(geom: &LayerGeom) -> LayerParams {
    let uf = geom.cnum.min(1024);
    let trips = (geom.cnum as u64).div_ceil(uf as u64);
    let target = 12_288u64;
    let p = ((geom.dep as u64 * trips).div_ceil(target)).next_power_of_two() as usize;
    LayerParams::new(uf, p.max(1))
}

/// eq. 9 — total sequential XNOR-accumulate cycles of a layer.
pub fn cycle_conv(geom: &LayerGeom) -> u64 {
    geom.outputs() * geom.cnum as u64
}

/// eq. 11 — estimated cycles with unfolding UF, parallelism P, interval I.
pub fn cycle_est(geom: &LayerGeom, params: &LayerParams) -> u64 {
    let denom = params.lanes();
    (cycle_conv(geom)).div_ceil(denom) * params.ii as u64
}

/// Microarchitecture model of the Vivado-HLS-measured `Cycle_r`.
pub fn cycle_real(geom: &LayerGeom, params: &LayerParams, model: &PipelineModel) -> u64 {
    let rows = geom.hei as u64;
    // output positions per row, processed P at a time, each needing
    // cnum/UF pipelined trips
    let groups_per_row = ((geom.wid * geom.dep) as u64).div_ceil(params.p as u64);
    let trips_per_group = (geom.cnum as u64).div_ceil(params.uf as u64);
    let trips_row = groups_per_row * trips_per_group * params.ii as u64;
    let depth = (params.uf.max(2) as f64).log2().ceil() as u64 + model.base_stages;
    rows * (trips_row + depth - 1 + model.row_ctrl) + model.layer_ctrl
}

/// eq. 12 — steady-state system FPS given per-layer cycles and the clock.
pub fn system_fps(per_layer_cycles: &[u64], freq_hz: f64) -> f64 {
    let bottleneck = per_layer_cycles.iter().copied().max().unwrap_or(0);
    if bottleneck == 0 {
        return 0.0;
    }
    freq_hz / bottleneck as f64
}

/// Single-image pipeline latency: with double-buffered phases every image
/// traverses `L` phases of the bottleneck length (§4.3).
pub fn pipeline_latency_s(per_layer_cycles: &[u64], freq_hz: f64) -> f64 {
    let bottleneck = per_layer_cycles.iter().copied().max().unwrap_or(0) as f64;
    per_layer_cycles.len() as f64 * bottleneck / freq_hz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::layer_geometry;
    use crate::model::NetConfig;

    fn paper_conv_params() -> Vec<LayerParams> {
        paper_table3_conv_params()
    }

    #[test]
    fn table3_cycle_est_exact() {
        let geoms = layer_geometry(&NetConfig::table2());
        let params = paper_conv_params();
        let est: Vec<u64> = geoms[..6]
            .iter()
            .zip(&params)
            .map(|(g, p)| cycle_est(g, p))
            .collect();
        assert_eq!(est, vec![4096, 12288, 12288, 12288, 12288, 12288]);
    }

    #[test]
    fn cycle_real_close_to_paper() {
        // paper Table 3 Cycle_r: 5233, 12386, 12296, 13329, 12386, 14473.
        // our microarchitecture model must land within 20% per layer and
        // within 20% on the bottleneck.
        let paper_r = [5233u64, 12386, 12296, 13329, 12386, 14473];
        let geoms = layer_geometry(&NetConfig::table2());
        let params = paper_conv_params();
        let model = PipelineModel::default();
        for ((g, p), &want) in geoms[..6].iter().zip(&params).zip(&paper_r) {
            let got = cycle_real(g, p, &model);
            let err = (got as f64 - want as f64).abs() / want as f64;
            assert!(err < 0.25, "{}: got {got}, paper {want} ({:.1}% off)", g.name, err * 100.0);
            assert!(got >= cycle_est(g, p), "real >= est");
        }
    }

    #[test]
    fn fps_headline_shape() {
        // paper §6.2: 6218 FPS at 90 MHz (bottleneck 14473 cycles).  Our
        // model's bottleneck must give the same order: within 25%.
        let geoms = layer_geometry(&NetConfig::table2());
        let params = paper_conv_params();
        let model = PipelineModel::default();
        let cycles: Vec<u64> = geoms[..6]
            .iter()
            .zip(&params)
            .map(|(g, p)| cycle_real(g, p, &model))
            .collect();
        let fps = system_fps(&cycles, 90.0e6);
        assert!((fps - 6218.0).abs() / 6218.0 < 0.25, "fps {fps}");
    }

    #[test]
    fn est_divides_exactly_for_paper_params() {
        // UF*P divides Cycle_conv for every Table 3 row
        let geoms = layer_geometry(&NetConfig::table2());
        for (g, p) in geoms[..6].iter().zip(paper_conv_params()) {
            assert_eq!(cycle_conv(g) % p.lanes(), 0, "{}", g.name);
        }
    }

    #[test]
    fn system_fps_empty_is_zero() {
        assert_eq!(system_fps(&[], 90e6), 0.0);
    }
}
