//! Double-buffered inter-layer memory channels (paper §4.3, fig. 4).
//!
//! Each channel has two slots.  During a phase, the producer layer writes
//! into the *back* slot while the consumer reads the *front* slot; when the
//! phase ends (all layers done) every channel swaps.  This is the
//! data-flow control that lets all layers run concurrently — the paper's
//! streaming architecture — and is what makes system throughput eq. 12's
//! `max(C_L)` instead of `sum(C_L)`.

/// A two-slot ping-pong buffer carrying `T` between adjacent layers.
#[derive(Debug, Clone)]
pub struct DoubleBuffer<T> {
    slots: [Option<T>; 2],
    /// Index of the slot the consumer reads this phase.
    front: usize,
    writes: u64,
    swaps: u64,
}

impl<T> Default for DoubleBuffer<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DoubleBuffer<T> {
    pub fn new() -> Self {
        Self { slots: [None, None], front: 0, writes: 0, swaps: 0 }
    }

    /// Producer side: write this phase's output into the back slot.
    /// Returns an error if the back slot is still occupied (the consumer
    /// has not drained it — a scheduling bug, not a data race).
    pub fn write(&mut self, value: T) -> Result<(), &'static str> {
        let back = 1 - self.front;
        if self.slots[back].is_some() {
            return Err("double-buffer overwrite: back slot still full");
        }
        self.slots[back] = Some(value);
        self.writes += 1;
        Ok(())
    }

    /// Consumer side: take the front slot's value (empties it).
    pub fn read(&mut self) -> Option<T> {
        self.slots[self.front].take()
    }

    /// Peek without consuming (layer may re-read during its phase).
    pub fn peek(&self) -> Option<&T> {
        self.slots[self.front].as_ref()
    }

    /// Phase boundary: swap front and back.
    pub fn swap(&mut self) {
        self.front = 1 - self.front;
        self.swaps += 1;
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    pub fn swaps(&self) -> u64 {
        self.swaps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pingpong_flow() {
        let mut ch = DoubleBuffer::new();
        assert!(ch.read().is_none());
        ch.write(1).unwrap();
        // produced into back: not visible until swap
        assert!(ch.read().is_none());
        ch.swap();
        assert_eq!(ch.peek(), Some(&1));
        assert_eq!(ch.read(), Some(1));
        assert!(ch.read().is_none());
    }

    #[test]
    fn overwrite_detected() {
        let mut ch = DoubleBuffer::new();
        ch.write(1).unwrap();
        assert!(ch.write(2).is_err());
        ch.swap();
        ch.write(2).unwrap(); // back slot is now the drained one? no:
        // after swap, front holds 1 (unread), back is empty -> write ok
        assert_eq!(ch.read(), Some(1));
    }

    #[test]
    fn steady_state_pipeline() {
        // producer writes every phase, consumer reads every phase, offset 1
        let mut ch = DoubleBuffer::new();
        let mut consumed = Vec::new();
        for t in 0..10 {
            if let Some(v) = ch.read() {
                consumed.push(v);
            }
            ch.write(t).unwrap();
            ch.swap();
        }
        assert_eq!(consumed, vec![0, 1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(ch.writes(), 10);
        assert_eq!(ch.swaps(), 10);
    }
}
