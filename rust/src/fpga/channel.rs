//! Double-buffered inter-layer memory channels (paper §4.3, fig. 4).
//!
//! Each channel has two slots.  During a phase, the producer layer writes
//! into the *back* slot while the consumer reads the *front* slot; when the
//! phase ends (all layers done) every channel swaps.  This is the
//! data-flow control that lets all layers run concurrently — the paper's
//! streaming architecture — and is what makes system throughput eq. 12's
//! `max(C_L)` instead of `sum(C_L)`.

/// Slots per inter-layer channel: the paper's §4.3 channels are *double*
/// buffered (one slot being produced while the other is consumed).  This
/// constant is the single source of truth for inter-layer buffer depth —
/// both the phase simulator's [`DoubleBuffer`] and the row-streaming
/// pipeline runtime's FIFO capacity ([`fifo_rows`]) derive from it.
pub const CHANNEL_SLOTS: usize = 2;

/// Row capacity of a software FIFO standing in for a double-buffered
/// inter-layer channel whose slots each hold one feature map of
/// `rows_per_image` rows.  `CHANNEL_SLOTS` slots x one image of rows per
/// slot — the row-streaming pipeline can hold exactly as much in-flight
/// data between two adjacent layers as the paper's ping-pong memory does
/// (`rows_per_image` is clamped to >= 1 so degenerate 1-pixel FC "maps"
/// still get a usable channel).
pub const fn fifo_rows(rows_per_image: usize) -> usize {
    let rows = if rows_per_image == 0 { 1 } else { rows_per_image };
    CHANNEL_SLOTS * rows
}

/// A two-slot ping-pong buffer carrying `T` between adjacent layers.
#[derive(Debug, Clone)]
pub struct DoubleBuffer<T> {
    slots: [Option<T>; CHANNEL_SLOTS],
    /// Index of the slot the consumer reads this phase.
    front: usize,
    writes: u64,
    swaps: u64,
}

impl<T> Default for DoubleBuffer<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DoubleBuffer<T> {
    pub fn new() -> Self {
        Self { slots: [None, None], front: 0, writes: 0, swaps: 0 }
    }

    /// Producer side: write this phase's output into the back slot.
    /// Returns an error if the back slot is still occupied (the consumer
    /// has not drained it — a scheduling bug, not a data race).
    pub fn write(&mut self, value: T) -> Result<(), &'static str> {
        let back = 1 - self.front;
        if self.slots[back].is_some() {
            return Err("double-buffer overwrite: back slot still full");
        }
        self.slots[back] = Some(value);
        self.writes += 1;
        Ok(())
    }

    /// Consumer side: take the front slot's value (empties it).
    pub fn read(&mut self) -> Option<T> {
        self.slots[self.front].take()
    }

    /// Peek without consuming (layer may re-read during its phase).
    pub fn peek(&self) -> Option<&T> {
        self.slots[self.front].as_ref()
    }

    /// Phase boundary: swap front and back.
    pub fn swap(&mut self) {
        self.front = 1 - self.front;
        self.swaps += 1;
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    pub fn swaps(&self) -> u64 {
        self.swaps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_rows_derives_from_channel_geometry() {
        // the pipeline runtime's FIFO depth and the simulator's ping-pong
        // buffer must never drift apart: both are CHANNEL_SLOTS deep
        assert_eq!(CHANNEL_SLOTS, 2, "paper §4.3: channels are double-buffered");
        for rows in [1usize, 2, 8, 32] {
            assert_eq!(fifo_rows(rows), CHANNEL_SLOTS * rows);
        }
        // degenerate 1-pixel FC maps still get a two-slot channel
        assert_eq!(fifo_rows(0), CHANNEL_SLOTS);
    }

    #[test]
    fn pingpong_flow() {
        let mut ch = DoubleBuffer::new();
        assert!(ch.read().is_none());
        ch.write(1).unwrap();
        // produced into back: not visible until swap
        assert!(ch.read().is_none());
        ch.swap();
        assert_eq!(ch.peek(), Some(&1));
        assert_eq!(ch.read(), Some(1));
        assert!(ch.read().is_none());
    }

    #[test]
    fn overwrite_detected() {
        let mut ch = DoubleBuffer::new();
        ch.write(1).unwrap();
        assert!(ch.write(2).is_err());
        ch.swap();
        ch.write(2).unwrap(); // back slot is now the drained one? no:
        // after swap, front holds 1 (unread), back is empty -> write ok
        assert_eq!(ch.read(), Some(1));
    }

    #[test]
    fn steady_state_pipeline() {
        // producer writes every phase, consumer reads every phase, offset 1
        let mut ch = DoubleBuffer::new();
        let mut consumed = Vec::new();
        for t in 0..10 {
            if let Some(v) = ch.read() {
                consumed.push(v);
            }
            ch.write(t).unwrap();
            ch.swap();
        }
        assert_eq!(consumed, vec![0, 1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(ch.writes(), 10);
        assert_eq!(ch.swaps(), 10);
    }
}
