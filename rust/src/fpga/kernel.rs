//! Computing kernel (paper fig. 6): a P-wide array of PEs with DSP
//! accumulators, followed by the fused MaxPool and NormBinarize kernels.
//!
//! This is a second, *independent* functional implementation of a binary
//! layer — it walks output values in PE groups and accumulates UF-wide
//! trip partial counts exactly like the hardware datapath, rather than the
//! engine's whole-row popcount.  Tests assert the two agree bit-exactly,
//! which validates both the engine's packed tricks and this datapath
//! model.  It also reports the cycle count its walk implies, which must
//! equal `timing::cycle_est` for exact-divisor parameters.

use anyhow::{bail, Result};

use crate::bcnn::tensor::{Activation, BitFmap};
use crate::bcnn::LayerOutput;
use crate::fpga::pe::Pe;
use crate::fpga::timing::LayerParams;
use crate::model::LayerWeights;
use crate::util::bits::{copy_bits, words_for};

/// Result of simulating one layer on the kernel datapath.
#[derive(Debug)]
pub struct KernelRun {
    pub output: LayerOutput,
    /// Pipelined trip count the walk performed (= Cycle_est for II=1 and
    /// exact-divisor UF/P).
    pub trips: u64,
    /// PE groups scheduled (output values / P).
    pub groups: u64,
}

/// Execute one binary layer (conv or FC) through the PE-array datapath.
pub fn run_layer(layer: &LayerWeights, input: &Activation, params: &LayerParams) -> Result<KernelRun> {
    match layer {
        LayerWeights::BinConv { in_c, out_c, pool, words_per_row, thresholds, .. } => {
            let Activation::Bits(fmap) = input else {
                bail!("BinConv expects binary input");
            };
            let hw = fmap.hw;
            let cnum = 9 * in_c;
            let pe = Pe::new(params.uf.min(cnum));
            let mut trips = 0u64;
            let mut groups = 0u64;
            let mut plane = vec![0i32; hw * hw * out_c];
            let mut patch = vec![0u64; words_for(cnum)];
            // walk output values in groups of P (row-major over (y, x, n))
            let mut pending = 0usize;
            for y in 0..hw {
                for x in 0..hw {
                    gather_patch(fmap, y, x, *in_c, &mut patch);
                    for n in 0..*out_c {
                        let w = &layer_rows(layer)[n * words_per_row..(n + 1) * words_per_row];
                        plane[(y * hw + x) * out_c + n] = pe.dot(&patch, w, cnum);
                        pending += 1;
                        if pending == params.p {
                            pending = 0;
                            groups += 1;
                            trips += pe.trips(cnum);
                        }
                    }
                }
            }
            if pending > 0 {
                groups += 1;
                trips += pe.trips(cnum);
            }
            let (plane, out_hw) = if *pool { pool2x2(&plane, hw, *out_c) } else { (plane, hw) };
            let mut bits = BitFmap::zeros(out_hw, *out_c);
            for py in 0..out_hw {
                for px in 0..out_hw {
                    for ch in 0..*out_c {
                        bits.set(py, px, ch, plane[(py * out_hw + px) * out_c + ch] >= thresholds[ch]);
                    }
                }
            }
            Ok(KernelRun { output: LayerOutput::Act(Activation::Bits(bits)), trips, groups })
        }
        LayerWeights::BinFc { in_f, out_f, words_per_row, thresholds, .. } => {
            let row = fc_input(input, *in_f)?;
            let pe = Pe::new(params.uf.min(*in_f));
            let mut bits = BitFmap::zeros(1, *out_f);
            let mut trips = 0u64;
            for n in 0..*out_f {
                let w = &layer_rows(layer)[n * words_per_row..(n + 1) * words_per_row];
                bits.set(0, 0, n, pe.dot(&row, w, *in_f) >= thresholds[n]);
                trips += pe.trips(*in_f);
            }
            let groups = (*out_f as u64).div_ceil(params.p as u64);
            // P PEs share trips across output neurons
            let trips = trips.div_ceil(params.p as u64);
            Ok(KernelRun { output: LayerOutput::Act(Activation::Bits(bits)), trips, groups })
        }
        LayerWeights::BinFcOut { in_f, out_f, words_per_row, scale, bias, .. } => {
            let row = fc_input(input, *in_f)?;
            let pe = Pe::new(params.uf.min(*in_f));
            let mut scores = Vec::with_capacity(*out_f);
            let mut trips = 0u64;
            for n in 0..*out_f {
                let w = &layer_rows(layer)[n * words_per_row..(n + 1) * words_per_row];
                scores.push(pe.dot(&row, w, *in_f) as f32 * scale[n] + bias[n]);
                trips += pe.trips(*in_f);
            }
            let groups = (*out_f as u64).div_ceil(params.p as u64);
            let trips = trips.div_ceil(params.p as u64);
            Ok(KernelRun { output: LayerOutput::Scores(scores), trips, groups })
        }
        LayerWeights::FpConv { .. } => bail!("FpConv runs on the DSP datapath, not the PE array"),
    }
}

fn layer_rows(layer: &LayerWeights) -> &[u64] {
    match layer {
        LayerWeights::BinConv { weights, .. }
        | LayerWeights::BinFc { weights, .. }
        | LayerWeights::BinFcOut { weights, .. } => weights,
        LayerWeights::FpConv { .. } => unreachable!(),
    }
}

fn gather_patch(fmap: &BitFmap, y: usize, x: usize, in_c: usize, patch: &mut [u64]) {
    patch.iter_mut().for_each(|v| *v = 0);
    let hw = fmap.hw;
    for kh in 0..3usize {
        let sy = y as isize + kh as isize - 1;
        if sy < 0 || sy >= hw as isize {
            continue;
        }
        for kw in 0..3usize {
            let sx = x as isize + kw as isize - 1;
            if sx < 0 || sx >= hw as isize {
                continue;
            }
            copy_bits(patch, (kh * 3 + kw) * in_c, fmap.pixel(sy as usize, sx as usize), 0, in_c);
        }
    }
}

fn pool2x2(plane: &[i32], hw: usize, c: usize) -> (Vec<i32>, usize) {
    let oh = hw / 2;
    let mut out = vec![i32::MIN; oh * oh * c];
    for py in 0..oh {
        for px in 0..oh {
            for ch in 0..c {
                let mut best = i32::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        best = best.max(plane[((py * 2 + dy) * hw + px * 2 + dx) * c + ch]);
                    }
                }
                out[(py * oh + px) * c + ch] = best;
            }
        }
    }
    (out, oh)
}

fn fc_input(input: &Activation, in_f: usize) -> Result<Vec<u64>> {
    match input {
        Activation::Bits(f) => {
            if f.hw * f.hw * f.c != in_f {
                bail!("FC input features {} != {in_f}", f.hw * f.hw * f.c);
            }
            Ok(f.flatten())
        }
        Activation::Int { .. } => bail!("FC expects binary input"),
    }
}
