//! Processing element (paper fig. 5): a UF-wide XNOR gate array feeding a
//! parallel bit-count (popcount) tree, iterated `cnum/UF` times per output
//! value with the partial counts accumulated downstream (fig. 6 DSP
//! accumulators).
//!
//! The functional model is bit-exact (tests check it against the packed
//! engine); the latency model exposes the per-stage depth that
//! `timing::cycle_real` uses for pipeline fill.

use crate::util::bits::read_bits_u64;

/// One PE instance: UF XNOR lanes + popcount tree.
#[derive(Debug, Clone, Copy)]
pub struct Pe {
    pub uf: usize,
}

impl Pe {
    pub fn new(uf: usize) -> Self {
        assert!(uf >= 1, "UF must be >= 1");
        Self { uf }
    }

    /// Popcount-tree depth in pipeline stages (log2 levels of 6:3
    /// compressors; the paper's "deep pipeline stages").
    pub fn tree_depth(&self) -> u64 {
        (self.uf.max(2) as f64).log2().ceil() as u64
    }

    /// Trips through the PE per output value (temporal reuse, §4.2.1).
    pub fn trips(&self, cnum: usize) -> u64 {
        (cnum as u64).div_ceil(self.uf as u64)
    }

    /// One pipeline trip: XNOR + popcount over lanes `[trip*UF, trip*UF+UF)`
    /// of the packed activation patch and weight row.  Lanes beyond `cnum`
    /// contribute zero (the hardware masks the tail).
    pub fn trip_matches(&self, patch: &[u64], weights: &[u64], trip: u64, cnum: usize) -> u32 {
        let start = trip as usize * self.uf;
        let end = (start + self.uf).min(cnum);
        debug_assert!(start < cnum);
        let mut matches = 0u32;
        let mut off = start;
        while off < end {
            let n = (end - off).min(64);
            let a = read_bits_u64(patch, off, n);
            let w = read_bits_u64(weights, off, n);
            // XNOR match count within the n-bit chunk
            let xnor = !(a ^ w);
            let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
            matches += (xnor & mask).count_ones();
            off += n;
        }
        matches
    }

    /// Full XnorDotProduct of one output value: sum of all trips — must
    /// equal `cnum - popcount(patch ^ weights)` computed by the engine.
    pub fn dot(&self, patch: &[u64], weights: &[u64], cnum: usize) -> i32 {
        (0..self.trips(cnum))
            .map(|t| self.trip_matches(patch, weights, t, cnum))
            .sum::<u32>() as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits::{set_bit, words_for, xor_popcount};
    use crate::util::SplitMix64;

    fn random_row(rng: &mut SplitMix64, bits: usize) -> Vec<u64> {
        let mut row = vec![0u64; words_for(bits)];
        for i in 0..bits {
            set_bit(&mut row, i, rng.bit());
        }
        row
    }

    #[test]
    fn dot_equals_engine_formula_property() {
        let mut rng = SplitMix64::new(10);
        for _ in 0..200 {
            let cnum = 1 + rng.below(700) as usize;
            let uf = 1 + rng.below(cnum as u64) as usize;
            let a = random_row(&mut rng, cnum);
            let w = random_row(&mut rng, cnum);
            let pe = Pe::new(uf);
            let want = cnum as i32 - xor_popcount(&a, &w) as i32;
            assert_eq!(pe.dot(&a, &w, cnum), want, "cnum={cnum} uf={uf}");
        }
    }

    #[test]
    fn trips_count() {
        assert_eq!(Pe::new(384).trips(1152), 3);
        assert_eq!(Pe::new(27).trips(27), 1);
        assert_eq!(Pe::new(100).trips(101), 2);
    }

    #[test]
    fn tree_depth_monotone() {
        assert_eq!(Pe::new(2).tree_depth(), 1);
        assert_eq!(Pe::new(384).tree_depth(), 9);
        assert_eq!(Pe::new(1536).tree_depth(), 11);
    }

    #[test]
    fn partial_trips_sum_to_dot() {
        let mut rng = SplitMix64::new(11);
        let cnum = 130;
        let a = random_row(&mut rng, cnum);
        let w = random_row(&mut rng, cnum);
        let pe = Pe::new(64);
        let parts: Vec<u32> = (0..pe.trips(cnum)).map(|t| pe.trip_matches(&a, &w, t, cnum)).collect();
        assert_eq!(parts.len(), 3);
        assert!(parts[2] <= 2); // tail trip covers only 2 lanes
        assert_eq!(parts.iter().sum::<u32>() as i32, pe.dot(&a, &w, cnum));
    }

    #[test]
    #[should_panic(expected = "UF")]
    fn zero_uf_panics() {
        Pe::new(0);
    }
}
