//! BRAM banking model (paper §5.3).
//!
//! The paper maps all weights onto on-chip block RAM, *reshaping* arrays to
//! the 32-bit maximum BRAM word and *partitioning* them across banks so
//! each PE array can read its UF weight bits every cycle.  Feature maps go
//! to distributed RAM (LUTs), and per-feature-map accumulator intermediates
//! go to BRAM (fig. 6).  This module computes the bank counts that banking
//! discipline implies — the BRAM column of Table 4.

use super::LayerGeom;
use crate::fpga::timing::LayerParams;

/// Virtex-7 36Kb block RAM.
pub const BRAM_BITS: u64 = 36 * 1024;
/// Paper §5.3: "the maximum word length of a BRAM ... is limited to 32
/// bits", so arrays are reshaped by 32 before partitioning.
pub const BRAM_WORD: u64 = 32;
/// CAL: partition fragmentation overhead observed in HLS-generated banking
/// (banks sized to power-of-two depths, per-partition waste).
pub const PARTITION_OVERHEAD: f64 = 1.10;
/// Accumulator intermediates are double-buffered 16-bit values (fig. 6:
/// bit-count results within a single feature map live in BRAM).
pub const ACC_BITS: u64 = 16;

/// BRAM allocation for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BramAlloc {
    /// Banks needed to stream UF weight bits per cycle.
    pub bandwidth_banks: u64,
    /// Banks needed to hold the layer's weights.
    pub capacity_banks: u64,
    /// Banks for double-buffered accumulator intermediates.
    pub accumulator_banks: u64,
    /// Final allocation (max of bandwidth/capacity shaping + accumulators,
    /// with partition overhead).
    pub total: u64,
}

/// Weight storage bits for a layer (first layer weights are 2-bit signed).
pub fn weight_bits(geom: &LayerGeom) -> u64 {
    let per_filter = geom.cnum as u64;
    let bits = if geom.fixed_point { 2 * per_filter } else { per_filter };
    geom.dep as u64 * bits
}

/// Bank the weights of one layer.
///
/// The weight array is partitioned into `ceil(UF_bits / 32)` banks so one
/// 32-bit word from each bank supplies the PE array's UF lanes per cycle
/// (weights are broadcast across the P PEs of a layer — all PEs apply the
/// same filter to different output positions).  Each bank must then hold
/// `weight_bits / banks`, rounded up to whole BRAMs.
pub fn weight_brams(geom: &LayerGeom, params: &LayerParams) -> BramAlloc {
    let bits = weight_bits(geom);
    let uf_bits = if geom.fixed_point { 2 * params.uf as u64 } else { params.uf as u64 };
    let bandwidth_banks = uf_bits.div_ceil(BRAM_WORD);
    let capacity_banks = bits.div_ceil(BRAM_BITS);
    let bits_per_bank = bits.div_ceil(bandwidth_banks);
    let brams_per_bank = bits_per_bank.div_ceil(BRAM_BITS);
    let shaped = bandwidth_banks * brams_per_bank;
    let acc = accumulator_brams(geom);
    let total = ((shaped.max(capacity_banks) as f64) * PARTITION_OVERHEAD).ceil() as u64 + acc;
    BramAlloc { bandwidth_banks, capacity_banks, accumulator_banks: acc, total }
}

/// Double-buffered accumulator intermediates of one feature map (fig. 6).
pub fn accumulator_brams(geom: &LayerGeom) -> u64 {
    if !geom.is_conv {
        // FC intermediates are a single vector — negligible, one bank
        return 1;
    }
    let bits = geom.outputs() * ACC_BITS * 2; // double-buffered
    bits.div_ceil(BRAM_BITS)
}

/// Total BRAM for a network plan.
pub fn total_brams(geoms: &[LayerGeom], params: &[LayerParams]) -> u64 {
    geoms
        .iter()
        .zip(params)
        .map(|(g, p)| weight_brams(g, p).total)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::layer_geometry;
    use crate::fpga::timing::{paper_fc_params, paper_table3_conv_params};
    use crate::model::NetConfig;

    fn table2_plan() -> (Vec<LayerGeom>, Vec<LayerParams>) {
        let geoms = layer_geometry(&NetConfig::table2());
        let mut params = paper_table3_conv_params();
        for g in &geoms[6..] {
            params.push(paper_fc_params(g));
        }
        (geoms, params)
    }

    #[test]
    fn weight_bits_table2() {
        let geoms = layer_geometry(&NetConfig::table2());
        assert_eq!(weight_bits(&geoms[0]), 2 * 27 * 128); // 2-bit first layer
        assert_eq!(weight_bits(&geoms[1]), 1152 * 128);
        assert_eq!(weight_bits(&geoms[6]), 8192 * 1024);
    }

    #[test]
    fn bandwidth_banks_follow_uf() {
        let geoms = layer_geometry(&NetConfig::table2());
        let params = paper_table3_conv_params();
        // conv2: UF=384 -> 12 banks of 32 bits
        assert_eq!(weight_brams(&geoms[1], &params[1]).bandwidth_banks, 12);
        // conv6: UF=1536 -> 48
        assert_eq!(weight_brams(&geoms[5], &params[5]).bandwidth_banks, 48);
    }

    #[test]
    fn total_brams_close_to_table4() {
        // paper Table 4: 1007 BRAMs used (48.88% of 2060)
        let (geoms, params) = table2_plan();
        let total = total_brams(&geoms, &params);
        let err = (total as f64 - 1007.0).abs() / 1007.0;
        assert!(err < 0.20, "total {total} vs paper 1007 ({:.1}% off)", err * 100.0);
    }

    #[test]
    fn capacity_dominates_fc() {
        let (geoms, params) = table2_plan();
        let fc1 = weight_brams(&geoms[6], &params[6]);
        assert!(fc1.capacity_banks >= 228, "fc1 {:?}", fc1);
        assert!(fc1.total >= fc1.capacity_banks);
    }
}
