//! FPGA resource-utilization model — regenerates Table 4.
//!
//! Structure comes from the paper's mapping strategy (§5, fig. 6):
//!
//! * binary kernels (XNOR array + popcount tree + routing) -> **LUTs**;
//! * feature maps (double-buffered) -> **distributed RAM** (more LUTs);
//! * weights + accumulator intermediates -> **BRAM** ([`super::memory`]);
//! * first-layer fixed-point MACs and per-PE accumulate/compare chains ->
//!   **DSP48**;
//! * pipeline stages -> **registers**.
//!
//! Per-lane coefficients are calibrated (CAL) against the paper's Table 4
//! implementation report; the *structure* (what scales with UF*P, what
//! with feature-map bits, what with P) is first-principles.

use super::{memory, LayerGeom};
use crate::fpga::timing::LayerParams;

/// Device budgets (paper Table 4 "Available" row: Virtex-7 XC7VX690).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    pub luts: u64,
    pub brams: u64,
    pub registers: u64,
    pub dsps: u64,
}

pub const VIRTEX7_690T: Device =
    Device { luts: 433_200, brams: 2_060, registers: 607_200, dsps: 2_800 };

// --- CAL coefficients (see module docs / DESIGN.md §2) -------------------
/// LUTs per XNOR lane: the paper's 2.5 XNORs per 6-input LUT (§2.4).
pub const LUT_PER_XNOR: f64 = 1.0 / 2.5;
/// LUTs per lane of popcount tree (6:3 compressor tree ~= 1.1 LUT/input).
pub const LUT_PER_POPCOUNT_LANE: f64 = 1.1;
/// CAL: HLS datapath routing/mux overhead per lane (weight/patch
/// multiplexing into the PE array dominates Table 4's LUT count).
pub const LUT_ROUTING_PER_LANE: f64 = 4.6;
/// Distributed-RAM: one LUT (RAM64X1S) per 64 feature-map bits, doubled
/// for the ping-pong buffer, plus an equal share of read muxing.
pub const LUT_PER_FMAP_BIT: f64 = 2.0 * 2.0 / 64.0;
/// Fixed control per layer (FSM, counters).
pub const LUT_LAYER_CTRL: f64 = 300.0;
/// CAL: pipeline registers per lane (partial-count staging).
pub const REG_PER_LANE: f64 = 1.33;
/// First layer: 6-bit x 2-bit MACs per DSP48 (two narrow mults pack per
/// slice with the paper's 30%-of-DSP report).
pub const FP_MACS_PER_DSP: f64 = 2.6;
/// CAL: DSP slices per PE accumulate/MP/NB chain (fig. 6 right side).
pub const DSP_PER_ACCUM: f64 = 9.2;

/// Per-layer resource usage.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerResources {
    pub luts: u64,
    pub registers: u64,
    pub brams: u64,
    pub dsps: u64,
}

/// Whole-design report (Table 4).
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceReport {
    pub per_layer: Vec<LayerResources>,
    pub total: LayerResources,
    pub device: Device,
}

impl ResourceReport {
    pub fn utilization(&self) -> (f64, f64, f64, f64) {
        (
            self.total.luts as f64 / self.device.luts as f64,
            self.total.brams as f64 / self.device.brams as f64,
            self.total.registers as f64 / self.device.registers as f64,
            self.total.dsps as f64 / self.device.dsps as f64,
        )
    }

    pub fn fits(&self) -> bool {
        self.total.luts <= self.device.luts
            && self.total.brams <= self.device.brams
            && self.total.registers <= self.device.registers
            && self.total.dsps <= self.device.dsps
    }
}

/// Resources of one layer under the given architectural parameters.
pub fn layer_resources(geom: &LayerGeom, params: &LayerParams) -> LayerResources {
    let lanes = params.lanes() as f64;
    let fmap_bits = geom.output_fmap_bits() as f64;
    let brams = memory::weight_brams(geom, params).total;

    if geom.fixed_point {
        // Layer 1: MACs on DSPs; LUTs only for control + fmap dist-RAM.
        let dsps = (lanes / FP_MACS_PER_DSP).ceil() + params.p as f64;
        let luts = LUT_LAYER_CTRL + fmap_bits * LUT_PER_FMAP_BIT + lanes * 1.0;
        LayerResources {
            luts: luts.round() as u64,
            registers: (lanes * REG_PER_LANE * 2.0).round() as u64, // wide int stages
            brams,
            dsps: dsps.round() as u64,
        }
    } else {
        let luts = lanes * (LUT_PER_XNOR + LUT_PER_POPCOUNT_LANE + LUT_ROUTING_PER_LANE)
            + fmap_bits * LUT_PER_FMAP_BIT
            + LUT_LAYER_CTRL;
        LayerResources {
            luts: luts.round() as u64,
            registers: (lanes * REG_PER_LANE).round() as u64,
            brams,
            dsps: (params.p as f64 * DSP_PER_ACCUM).round() as u64,
        }
    }
}

impl LayerGeom {
    /// Bits of this layer's (post-pool) output feature map, stored in
    /// distributed RAM (binary) or registers (layer-1 input handled by its
    /// producer).
    pub fn output_fmap_bits(&self) -> u64 {
        let spatial = if self.pool {
            (self.wid / 2) * (self.hei / 2)
        } else {
            self.wid * self.hei
        };
        (spatial * self.dep) as u64
    }
}

/// Full-design resource report.
pub fn report(geoms: &[LayerGeom], params: &[LayerParams], device: Device) -> ResourceReport {
    let per_layer: Vec<LayerResources> =
        geoms.iter().zip(params).map(|(g, p)| layer_resources(g, p)).collect();
    let total = per_layer.iter().fold(LayerResources::default(), |a, r| LayerResources {
        luts: a.luts + r.luts,
        registers: a.registers + r.registers,
        brams: a.brams + r.brams,
        dsps: a.dsps + r.dsps,
    });
    ResourceReport { per_layer, total, device }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::layer_geometry;
    use crate::fpga::timing::{paper_fc_params, paper_table3_conv_params};
    use crate::model::NetConfig;

    fn table2_report() -> ResourceReport {
        let geoms = layer_geometry(&NetConfig::table2());
        let mut params = paper_table3_conv_params();
        for g in &geoms[6..] {
            params.push(paper_fc_params(g));
        }
        report(&geoms, &params, VIRTEX7_690T)
    }

    #[test]
    fn table4_lut_within_band() {
        // paper: 342126 LUTs (78.98%)
        let r = table2_report();
        let err = (r.total.luts as f64 - 342_126.0).abs() / 342_126.0;
        assert!(err < 0.15, "LUTs {} vs 342126 ({:.1}% off)", r.total.luts, err * 100.0);
    }

    #[test]
    fn table4_dsp_within_band() {
        // paper: 1096 DSPs, ~30% consumed by layer 1
        let r = table2_report();
        let err = (r.total.dsps as f64 - 1096.0).abs() / 1096.0;
        assert!(err < 0.20, "DSPs {} vs 1096 ({:.1}% off)", r.total.dsps, err * 100.0);
        let l1_share = r.per_layer[0].dsps as f64 / r.total.dsps as f64;
        assert!((0.2..=0.45).contains(&l1_share), "layer-1 DSP share {l1_share}");
    }

    #[test]
    fn table4_registers_within_band() {
        // paper: 70769 registers (14.30%)
        let r = table2_report();
        let err = (r.total.registers as f64 - 70_769.0).abs() / 70_769.0;
        assert!(err < 0.25, "regs {} vs 70769 ({:.1}% off)", r.total.registers, err * 100.0);
    }

    #[test]
    fn design_fits_device() {
        let r = table2_report();
        assert!(r.fits(), "{:?} exceeds device", r.total);
        let (lut_u, bram_u, reg_u, dsp_u) = r.utilization();
        assert!(lut_u > 0.6 && lut_u < 0.95, "lut util {lut_u}");
        assert!(bram_u < 0.7, "bram util {bram_u}");
        assert!(reg_u < 0.3, "reg util {reg_u}");
        assert!(dsp_u < 0.6, "dsp util {dsp_u}");
    }

    #[test]
    fn resources_scale_with_parallelism() {
        let geoms = layer_geometry(&NetConfig::table2());
        let small = layer_resources(&geoms[1], &LayerParams::new(384, 8));
        let big = layer_resources(&geoms[1], &LayerParams::new(384, 32));
        assert!(big.luts > 3 * small.luts / 2, "{} vs {}", big.luts, small.luts);
        assert!(big.dsps > small.dsps);
    }
}
