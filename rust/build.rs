//! Probe the compiler for stable AVX-512 intrinsics support.
//!
//! The `_mm512_*` intrinsics used by `util::kernels::avx512` were
//! stabilised in Rust 1.89.  Older stable toolchains must still build the
//! crate (the dispatcher then reports the `avx512` tier as unavailable),
//! so instead of a hard MSRV bump we emit a `bcnn_avx512` cfg only when
//! the compiling rustc is new enough.  No external crates: parse
//! `rustc --version` by hand.

use std::env;
use std::process::Command;

const AVX512_STABLE: (u32, u32) = (1, 89);

fn rustc_version() -> Option<(u32, u32)> {
    let rustc = env::var_os("RUSTC").unwrap_or_else(|| "rustc".into());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // e.g. "rustc 1.89.0 (abcdef 2025-07-01)" or "rustc 1.91.0-nightly (...)"
    let ver = text.split_whitespace().nth(1)?;
    let ver = ver.split('-').next()?; // drop -nightly/-beta channel suffix
    let mut parts = ver.split('.');
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    // Declare the custom cfg so toolchains that enforce `--check-cfg`
    // accept it; cargos that predate check-cfg ignore the directive.
    println!("cargo:rustc-check-cfg=cfg(bcnn_avx512)");
    if let Some((major, minor)) = rustc_version() {
        if (major, minor) >= AVX512_STABLE {
            println!("cargo:rustc-cfg=bcnn_avx512");
        }
    }
    println!("cargo:rerun-if-changed=build.rs");
}
