"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each ``*_ref`` mirrors one kernel in this package with straight-line jnp,
and ``conv_pm1_ref`` implements the *textbook* ±1 BCNN convolution of
paper eq. (3) so the tests can prove the 1/0 reformulation of eq. (5)-(6)
exact: ``y_lo = 2 * y_l - cnum`` (paper eq. 6).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..packing import unpack_bits_jnp


def xnor_gemm_ref(a_packed: jnp.ndarray, w_packed: jnp.ndarray, k_bits: int) -> jnp.ndarray:
    """Match-count GEMM over packed binary operands.

    a_packed: uint32 [M, KW]; w_packed: uint32 [N, KW]; returns int32 [M, N]
    where out[m, n] = #bits where a[m] == w[n] over the first ``k_bits``
    bits (paper eq. 5, XnorDotProduct).  Trailing pad bits (if any) MUST be
    zero in both operands; matches over pad bits are excluded via k_bits.
    """
    a = unpack_bits_jnp(a_packed, a_packed.shape[-1] * 32)[..., :k_bits]
    w = unpack_bits_jnp(w_packed, w_packed.shape[-1] * 32)[..., :k_bits]
    # xnor(a, w) == 1 - xor(a, w) for bits
    mismatch = jnp.sum(jnp.abs(a[:, None, :] - w[None, :, :]), axis=-1)
    return (k_bits - mismatch).astype(jnp.int32)


def conv_pm1_ref(a_pm1: jnp.ndarray, w_pm1: jnp.ndarray) -> jnp.ndarray:
    """Textbook ±1 dot product of paper eq. (3): rows of a_pm1 [M, K] with
    rows of w_pm1 [N, K], all values in {+1, -1}; returns int32 [M, N]."""
    return jnp.dot(a_pm1.astype(jnp.int32), w_pm1.astype(jnp.int32).T)


def fp_gemm_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """First-layer fixed-point dot product (paper eq. 7): 6-bit signed
    activations [M, K] x 2-bit signed weights [N, K] -> int32 [M, N]."""
    return jnp.dot(a.astype(jnp.int32), w.astype(jnp.int32).T)


def norm_binarize_ref(y: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Comparator-based normalization (paper eq. 8): 1 if y >= c else 0.

    y: int32 [M, N]; c: int32 [N] per-output-channel threshold.
    """
    return (y >= c[None, :]).astype(jnp.int32)


def norm_affine_ref(y: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Output-layer Norm (paper fig. 3 last line): the non-binarized affine
    normalization score = scale * y + bias (scale/bias fold eq. 2 + eq. 6)."""
    return y.astype(jnp.float32) * scale[None, :] + bias[None, :]


def maxpool2x2_ref(y: jnp.ndarray) -> jnp.ndarray:
    """2x2/stride-2 max-pool over integer conv outputs, NHWC int32
    [B, H, W, C] -> [B, H//2, W//2, C] (paper §2.1.2 / fig. 3 MP)."""
    b, h, w, c = y.shape
    y = y.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(y, axis=(2, 4))
