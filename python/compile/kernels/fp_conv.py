"""L1 Pallas kernel: first-layer fixed-point GEMM (paper eq. 7).

The paper's first layer takes the 6-bit rescaled RGB image (values in
[-31, 31]) against 2-bit signed binary weights (±1); everything downstream
of im2col is an integer GEMM accumulated in int32.  On the FPGA this is the
one kernel mapped to DSP48 slices (~30% of DSP usage, §6.2); here it is a
plain MXU/ALU integer dot product — the input layer is <5% of total compute
(paper §3.1) so no bit tricks are warranted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 64
BN = 64


def _fp_gemm_kernel(a_ref, w_ref, o_ref):
    a = a_ref[...]  # [bm, k] int32
    w = w_ref[...]  # [bn, k] int32
    o_ref[...] = jax.lax.dot_general(
        a,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


def fp_gemm(a: jnp.ndarray, w: jnp.ndarray, *, bm: int = BM, bn: int = BN) -> jnp.ndarray:
    """Integer GEMM: int32 [M, K] x int32 [N, K] -> int32 [M, N].

    ``a`` holds 6-bit signed activations, ``w`` 2-bit signed weights; both
    are carried as int32 (zero-padding rows is exact for integer dot).
    """
    m, k = a.shape
    n, k2 = w.shape
    if k != k2:
        raise ValueError(f"K mismatch: {k} vs {k2}")
    a_p = _pad_rows(a.astype(jnp.int32), bm)
    w_p = _pad_rows(w.astype(jnp.int32), bn)
    mp, np_ = a_p.shape[0], w_p.shape[0]

    out = pl.pallas_call(
        _fp_gemm_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(a_p, w_p)
    return out[:m, :n]
