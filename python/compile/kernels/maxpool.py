"""L1 Pallas kernel: 2x2/2 max-pool over integer conv outputs.

The paper pools the *pre-binarization* accumulator outputs (fig. 3: MP runs
between XnorDotProduct and NormBinarize in layers 2, 4, 6) so the MP kernel
operates on int32 popcount results, in pipeline with the conv kernel
(§5.2).  NormBinarize's per-channel threshold is monotone, so pooling the
integers and pooling the bits commute — the tests assert this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(y_ref, o_ref):
    y = y_ref[...]  # [1, H, W, C] int32
    _, h, w, c = y.shape
    y = y.reshape(1, h // 2, 2, w // 2, 2, c)
    o_ref[...] = jnp.max(y, axis=(2, 4))


def maxpool2x2(y: jnp.ndarray) -> jnp.ndarray:
    """NHWC int32 [B, H, W, C] -> [B, H/2, W/2, C], 2x2 window, stride 2."""
    b, h, w, c = y.shape
    if h % 2 or w % 2:
        raise ValueError(f"H, W must be even, got {h}x{w}")
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, c), jnp.int32),
        interpret=True,
    )(y.astype(jnp.int32))
