"""L1 Pallas kernel: comparator-based NormBinarize (paper eq. 8).

The paper folds batch-norm (eq. 2), the Binarize sign function (eq. 4) and
the 1/0-encoding compensation (eq. 6) into one integer threshold compare
per output channel — a single LUT comparator on the FPGA, a single VPU
compare here.  The non-binarized output-layer ``Norm`` (fig. 3, last line)
is the affine variant ``scale * y + bias``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 256


def _norm_binarize_kernel(y_ref, c_ref, o_ref):
    y = y_ref[...]  # [bm, N] int32
    c = c_ref[...]  # [1, N] int32
    o_ref[...] = (y >= c).astype(jnp.int32)


def _norm_affine_kernel(y_ref, s_ref, b_ref, o_ref):
    y = y_ref[...].astype(jnp.float32)  # [bm, N]
    o_ref[...] = y * s_ref[...] + b_ref[...]


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


def norm_binarize(y: jnp.ndarray, c: jnp.ndarray, *, bm: int = BM) -> jnp.ndarray:
    """NormBinarize(y, c) = 1 if y >= c else 0 (paper eq. 8).

    y: int32 [M, N]; c: int32 [N] per-channel integer threshold
    (c_l = round((cnum_l + mu - beta*sigma'/gamma) / 2), paper §3.2).
    Returns int32 {0,1} [M, N].
    """
    m, n = y.shape
    if c.shape != (n,):
        raise ValueError(f"threshold shape {c.shape} != ({n},)")
    y_p = _pad_rows(y.astype(jnp.int32), bm)
    mp = y_p.shape[0]
    out = pl.pallas_call(
        _norm_binarize_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.int32),
        interpret=True,
    )(y_p, c.astype(jnp.int32).reshape(1, n))
    return out[:m]


def norm_affine(y: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, *, bm: int = BM) -> jnp.ndarray:
    """Output-layer Norm: float scores = scale * y + bias.

    y: int32 [M, N]; scale/bias: float32 [N] folding batch-norm constants
    and the eq. 6 compensation; returns float32 [M, N] class scores.
    """
    m, n = y.shape
    y_p = _pad_rows(y.astype(jnp.int32), bm)
    mp = y_p.shape[0]
    out = pl.pallas_call(
        _norm_affine_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(y_p, scale.astype(jnp.float32).reshape(1, n), bias.astype(jnp.float32).reshape(1, n))
    return out[:m]
