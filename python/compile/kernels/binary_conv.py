"""L1 Pallas kernel: XnorDotProduct GEMM (paper eq. 5).

This is the compute hot-spot of the whole BCNN: every hidden layer
(convolutional *and* fully-connected) reduces to a match-count GEMM over
bit-packed operands once the L2 model has laid convolution patches out
im2col-style (paper §3.1).

TPU adaptation of the paper's LUT/XNOR-gate array (DESIGN.md
§Hardware-Adaptation): 32 binary channels are packed per uint32 lane so the
innermost FD reduction becomes ``popcount(xor(a, w))`` on integer vectors —
element-wise VPU work plus a lane reduction, the role the XNOR-gate + bit
count tree plays on the FPGA.  The grid tiles the (output-pixel M, filter N)
space; one (bm, kw) activation tile and one (bn, kw) weight tile are
VMEM-resident per grid step, mirroring how the paper's BRAM partitioning
feeds P parallel PEs.  ``interpret=True`` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain HLO
that the Rust runtime loads unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  bm*bn*kw int32 intermediates must stay comfortably
# inside VMEM (~16 MiB): 64*64*288*4 B = 4.5 MiB for the largest layer
# (conv6: kw = 512*9/32 = 144; FC1: kw = 256).
BM = 64
BN = 64


def _xnor_gemm_kernel(a_ref, w_ref, o_ref, *, k_bits: int):
    """One (bm, bn) output tile: match count = k_bits - popcount(a ^ w)."""
    a = a_ref[...]  # [bm, kw] uint32
    w = w_ref[...]  # [bn, kw] uint32
    mismatch = jax.lax.population_count(a[:, None, :] ^ w[None, :, :])
    mismatch = jnp.sum(mismatch.astype(jnp.int32), axis=-1)  # [bm, bn]
    o_ref[...] = jnp.int32(k_bits) - mismatch


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    m = x.shape[0]
    pad = (-m) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


def xnor_gemm(
    a_packed: jnp.ndarray,
    w_packed: jnp.ndarray,
    k_bits: int,
    *,
    bm: int = BM,
    bn: int = BN,
) -> jnp.ndarray:
    """Match-count GEMM: uint32 [M, KW] x uint32 [N, KW] -> int32 [M, N].

    out[m, n] = number of equal bits between a[m] and w[n] over the first
    ``k_bits`` bits.  Pad bits beyond ``k_bits`` must be zero in BOTH
    operands (they then xnor to 1 and are cancelled by the k_bits offset:
    we subtract mismatches from k_bits, so equal pad bits contribute 0).
    """
    m, kw = a_packed.shape
    n, kw2 = w_packed.shape
    if kw != kw2:
        raise ValueError(f"K mismatch: {kw} vs {kw2}")
    if not (0 < k_bits <= kw * 32):
        raise ValueError(f"k_bits={k_bits} out of range for {kw} words")
    a_p = _pad_rows(a_packed.astype(jnp.uint32), bm)
    w_p = _pad_rows(w_packed.astype(jnp.uint32), bn)
    mp, np_ = a_p.shape[0], w_p.shape[0]

    out = pl.pallas_call(
        functools.partial(_xnor_gemm_kernel, k_bits=k_bits),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(a_p, w_p)
    return out[:m, :n]
