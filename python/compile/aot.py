"""AOT lowering: jax (L2 + Pallas L1) -> HLO *text* artifacts for Rust.

HLO text, NOT ``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Each artifact is the *hardware-path* forward graph for one (config, batch)
pair, with the image batch as argument 0 and the folded model parameters as
the remaining arguments (order recorded in the ``.json`` manifest next to
the HLO).  The Rust runtime builds the parameter literals from the
``.bcnn`` file — weights stay hot-swappable without re-lowering.

Run as a module (from ``python/``)::

    python -m compile.aot --out ../artifacts          # default artifact set
    python -m compile.aot --config small --batch 4 --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import CONFIGS, BcnnConfig, forward_packed

# The default artifact set built by `make artifacts`.  (config, batch)
# pairs: small model at serving batch sizes, plus a tiny module used by the
# Rust runtime unit tests.
DEFAULT_SET = [("small", 1), ("small", 8), ("small", 16), ("tiny", 1)]


def param_manifest(config: BcnnConfig) -> list[dict]:
    """Deterministic parameter order for the lowered graph: for each layer
    ``w{l}`` then ``c{l}`` (hidden) or ``scale``+``bias`` (output layer).

    Shapes/dtypes describe the *jnp hardware params* (uint32-packed binary
    weights), the layout ``rust/src/runtime/params.rs`` reconstructs from a
    ``.bcnn`` file.
    """
    entries: list[dict] = []
    conv_shapes = config.conv_shapes()
    n_conv = len(conv_shapes)
    for i, (in_c, out_c, _, _, _) in enumerate(conv_shapes):
        layer = i + 1
        if layer == 1:
            entries.append({"name": f"w{layer}", "dtype": "s32", "shape": [out_c, 9 * in_c]})
        else:
            kw = (9 * in_c + 31) // 32
            entries.append({"name": f"w{layer}", "dtype": "u32", "shape": [out_c, kw]})
        entries.append({"name": f"c{layer}", "dtype": "s32", "shape": [out_c]})
    fc_shapes = config.fc_shapes()
    for j, (in_f, out_f) in enumerate(fc_shapes):
        layer = n_conv + 1 + j
        kw = (in_f + 31) // 32
        entries.append({"name": f"w{layer}", "dtype": "u32", "shape": [out_f, kw]})
        if j < len(fc_shapes) - 1:
            entries.append({"name": f"c{layer}", "dtype": "s32", "shape": [out_f]})
        else:
            entries.append({"name": "scale", "dtype": "f32", "shape": [out_f]})
            entries.append({"name": "bias", "dtype": "f32", "shape": [out_f]})
    return entries


_DTYPES = {"s32": jnp.int32, "u32": jnp.uint32, "f32": jnp.float32}


def lower_model(config: BcnnConfig, batch: int) -> tuple[str, list[dict]]:
    """Lower forward_packed(config) at the given batch size to HLO text."""
    manifest = param_manifest(config)

    def fn(x, *flat_params):
        params = {e["name"]: p for e, p in zip(manifest, flat_params)}
        return (forward_packed(params, x, config),)

    x_spec = jax.ShapeDtypeStruct(
        (batch, config.input_hw, config.input_hw, config.input_channels), jnp.int32
    )
    param_specs = [
        jax.ShapeDtypeStruct(tuple(e["shape"]), _DTYPES[e["dtype"]]) for e in manifest
    ]
    lowered = jax.jit(fn).lower(x_spec, *param_specs)
    return to_hlo_text(lowered), manifest


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit_model(config_name: str, batch: int, out_dir: Path) -> Path:
    config = CONFIGS[config_name]
    text, manifest = lower_model(config, batch)
    stem = f"model_{config_name}_b{batch}"
    hlo_path = out_dir / f"{stem}.hlo.txt"
    hlo_path.write_text(text)
    meta = {
        "config": config_name,
        "batch": batch,
        "input": {
            "dtype": "s32",
            "shape": [batch, config.input_hw, config.input_hw, config.input_channels],
        },
        "output": {"dtype": "f32", "shape": [batch, config.classes]},
        "params": manifest,
    }
    (out_dir / f"{stem}.json").write_text(json.dumps(meta, indent=2) + "\n")
    print(f"[aot] wrote {hlo_path} ({len(text)} chars)")
    return hlo_path


def emit_xnor_demo(out_dir: Path) -> Path:
    """A standalone xnor_gemm module for Rust runtime unit tests:
    uint32 [8, 4] x uint32 [8, 4] -> int32 [8, 8], k_bits = 128."""
    from .kernels.binary_conv import xnor_gemm

    def fn(a, w):
        return (xnor_gemm(a, w, 128, bm=8, bn=8),)

    spec = jax.ShapeDtypeStruct((8, 4), jnp.uint32)
    lowered = jax.jit(fn).lower(spec, spec)
    path = out_dir / "xnor_demo.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    print(f"[aot] wrote {path}")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", choices=sorted(CONFIGS), default=None)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--out", type=Path, default=Path("../artifacts"))
    args = ap.parse_args(argv)
    args.out.mkdir(parents=True, exist_ok=True)

    if args.config is not None:
        emit_model(args.config, args.batch, args.out)
        return
    for config_name, batch in DEFAULT_SET:
        emit_model(config_name, batch, args.out)
    emit_xnor_demo(args.out)


if __name__ == "__main__":
    main()
