"""Synthetic 10-class image dataset (CIFAR-10 stand-in, DESIGN.md §2).

The environment has no CIFAR-10 download, so the end-to-end training run
uses a deterministic synthetic task with the same tensor interface: RGB
images rescaled to 6-bit signed integers in [-31, 31] (paper §3.1), 10
classes, 3x32x32 (NHWC).  Each class is a low-frequency ±1 template;
samples are the template scaled into the 6-bit range plus Gaussian noise —
enough structure that a BCNN must actually learn the conv + threshold
pipeline, and enough noise that accuracy is a meaningful signal.
"""

from __future__ import annotations

import numpy as np

INPUT_LO, INPUT_HI = -31, 31


def class_templates(
    classes: int, hw: int, channels: int, rng: np.random.Generator
) -> np.ndarray:
    """±1 low-frequency templates [classes, hw, hw, channels]: random ±1 at
    hw/4 resolution, nearest-neighbour upsampled 4x."""
    base = rng.integers(0, 2, (classes, hw // 4, hw // 4, channels)) * 2 - 1
    return np.repeat(np.repeat(base, 4, axis=1), 4, axis=2).astype(np.int32)


def make_dataset(
    n_train: int,
    n_test: int,
    *,
    classes: int = 10,
    hw: int = 32,
    channels: int = 3,
    amplitude: float = 14.0,
    noise: float = 10.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train, y_train, x_test, y_test); x int32 NHWC in
    [-31, 31], y int32 class labels."""
    rng = np.random.default_rng(seed)
    templates = class_templates(classes, hw, channels, rng)

    def sample(n: int) -> tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, classes, n)
        x = templates[y] * amplitude + rng.normal(0.0, noise, (n, hw, hw, channels))
        x = np.clip(np.rint(x), INPUT_LO, INPUT_HI).astype(np.int32)
        return x, y.astype(np.int32)

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te
