"""``.bcnn`` model-file writer/reader — the weight interchange with Rust.

Binary little-endian format (mirrored by ``rust/src/model/file.rs``)::

    magic   b"BCNN"
    u32     version = 2
    u16     name_len; utf-8 name
    u32     input_hw, input_channels, input_bits, classes
    u32     n_layers
    layer records, in network order:
      u8 kind:
        0 = fp_conv   (first layer, 6-bit input x 2-bit weights)
        1 = bin_conv  (XNOR conv)
        2 = bin_fc    (hidden XNOR fully-connected)
        3 = bin_fc_out(classifier: affine Norm, no binarize)
      fp_conv : u32 in_c, out_c; u8 pool;
                i8  weights [out_c][9*in_c]      (±1, (kh,kw,c) order)
                i32 thresholds [out_c]
      bin_conv: u32 in_c, out_c; u8 pool;
                u64 weights [out_c][ceil(9*in_c/64)]  (LSB-first bits)
                i32 thresholds [out_c]
      bin_fc  : u32 in_f, out_f;
                u64 weights [out_f][ceil(in_f/64)]
                i32 thresholds [out_f]
      bin_fc_out: u32 in_f, out_f;
                u64 weights [out_f][ceil(in_f/64)]
                f32 scale [out_f]; f32 bias [out_f]

Bit order: bit ``b`` of word ``w`` = flattened input index ``w*64 + b``;
conv inputs flatten (kh, kw, c), FC inputs flatten (h, w, c) — identical to
the layouts in ``model.py``.  Trailing pad bits are zero.
"""

from __future__ import annotations

import dataclasses
import struct
from pathlib import Path

import numpy as np

from .packing import pack_bits_np64, unpack_bits_np64

MAGIC = b"BCNN"
VERSION = 2
KIND_FP_CONV = 0
KIND_BIN_CONV = 1
KIND_BIN_FC = 2
KIND_BIN_FC_OUT = 3


@dataclasses.dataclass
class LayerRecord:
    kind: int
    in_dim: int  # in_c for conv, in_features for fc
    out_dim: int
    pool: bool = False
    weights_i8: np.ndarray | None = None  # fp_conv
    weights_bits: np.ndarray | None = None  # {0,1} [out, K] for binary kinds
    thresholds: np.ndarray | None = None  # i32 [out]
    scale: np.ndarray | None = None  # f32 [out] (out layer)
    bias: np.ndarray | None = None  # f32 [out]


@dataclasses.dataclass
class BcnnFile:
    name: str
    input_hw: int
    input_channels: int
    input_bits: int
    classes: int
    layers: list[LayerRecord]


def write_bcnn(path: str | Path, model: BcnnFile) -> None:
    """Serialize ``model`` to ``path`` in the format above."""
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", VERSION)
    name_b = model.name.encode("utf-8")
    out += struct.pack("<H", len(name_b)) + name_b
    out += struct.pack(
        "<IIII", model.input_hw, model.input_channels, model.input_bits, model.classes
    )
    out += struct.pack("<I", len(model.layers))
    for layer in model.layers:
        out += struct.pack("<B", layer.kind)
        if layer.kind in (KIND_FP_CONV, KIND_BIN_CONV):
            out += struct.pack("<IIB", layer.in_dim, layer.out_dim, int(layer.pool))
        else:
            out += struct.pack("<II", layer.in_dim, layer.out_dim)
        if layer.kind == KIND_FP_CONV:
            w = np.ascontiguousarray(layer.weights_i8, dtype=np.int8)
            assert w.shape == (layer.out_dim, 9 * layer.in_dim), w.shape
            out += w.tobytes()
        else:
            k = 9 * layer.in_dim if layer.kind == KIND_BIN_CONV else layer.in_dim
            bits = np.ascontiguousarray(layer.weights_bits, dtype=np.int32)
            assert bits.shape == (layer.out_dim, k), (bits.shape, k)
            out += pack_bits_np64(bits).astype("<u8").tobytes()
        if layer.kind == KIND_BIN_FC_OUT:
            out += np.ascontiguousarray(layer.scale, dtype="<f4").tobytes()
            out += np.ascontiguousarray(layer.bias, dtype="<f4").tobytes()
        else:
            out += np.ascontiguousarray(layer.thresholds, dtype="<i4").tobytes()
    Path(path).write_bytes(bytes(out))


class _Reader:
    def __init__(self, data: bytes):
        self.data = data
        self.off = 0

    def take(self, n: int) -> bytes:
        b = self.data[self.off : self.off + n]
        if len(b) != n:
            raise ValueError("truncated .bcnn file")
        self.off += n
        return b

    def unpack(self, fmt: str):
        return struct.unpack(fmt, self.take(struct.calcsize(fmt)))

    def array(self, dtype: str, count: int) -> np.ndarray:
        a = np.frombuffer(self.take(count * np.dtype(dtype).itemsize), dtype=dtype)
        return a.copy()


def read_bcnn(path: str | Path) -> BcnnFile:
    """Parse a ``.bcnn`` file (round-trip test + tooling)."""
    r = _Reader(Path(path).read_bytes())
    if r.take(4) != MAGIC:
        raise ValueError("bad magic")
    (version,) = r.unpack("<I")
    if version != VERSION:
        raise ValueError(f"unsupported version {version}")
    (name_len,) = r.unpack("<H")
    name = r.take(name_len).decode("utf-8")
    hw, in_c, in_bits, classes = r.unpack("<IIII")
    (n_layers,) = r.unpack("<I")
    layers = []
    for _ in range(n_layers):
        (kind,) = r.unpack("<B")
        if kind in (KIND_FP_CONV, KIND_BIN_CONV):
            in_dim, out_dim, pool = r.unpack("<IIB")
            pool = bool(pool)
        elif kind in (KIND_BIN_FC, KIND_BIN_FC_OUT):
            in_dim, out_dim = r.unpack("<II")
            pool = False
        else:
            raise ValueError(f"bad layer kind {kind}")
        rec = LayerRecord(kind=kind, in_dim=in_dim, out_dim=out_dim, pool=pool)
        if kind == KIND_FP_CONV:
            rec.weights_i8 = r.array("<i1", out_dim * 9 * in_dim).reshape(
                out_dim, 9 * in_dim
            )
        else:
            k = 9 * in_dim if kind == KIND_BIN_CONV else in_dim
            kw = (k + 63) // 64
            words = r.array("<u8", out_dim * kw).reshape(out_dim, kw)
            rec.weights_bits = unpack_bits_np64(words, k)
        if kind == KIND_BIN_FC_OUT:
            rec.scale = r.array("<f4", out_dim)
            rec.bias = r.array("<f4", out_dim)
        else:
            rec.thresholds = r.array("<i4", out_dim)
        layers.append(rec)
    if r.off != len(r.data):
        raise ValueError(f"{len(r.data) - r.off} trailing bytes")
    return BcnnFile(name, hw, in_c, in_bits, classes, layers)
