"""L2: the paper's BCNN forward graph (fig. 3), composed from L1 kernels.

Two forward implementations live here:

* :func:`forward_packed` — the *hardware-path* inference graph: bit-packed
  activations, XnorDotProduct GEMMs, integer NormBinarize thresholds.  This
  is what ``aot.py`` lowers to HLO text for the Rust runtime, and what the
  Rust native engine (``rust/src/bcnn``) must match bit-exactly.
* :func:`forward_train` — the *training-path* float graph: ±1 weights and
  activations via straight-through estimators + batch-norm, numerically
  identical to the hardware path after threshold folding (paper §3.2).

Network configurations follow Table 2 of the paper (``TABLE2``), plus a
scaled-down ``SMALL`` variant for the trained end-to-end run and ``TINY``
for fast tests (DESIGN.md §2 documents the CIFAR-10 substitution).

Layout conventions: activations are NHWC; im2col patches flatten in
``(kh, kw, c)`` order; bit-packing is LSB-first (see ``packing.py``); FC
input flattens the feature map in ``(h, w, c)`` order.  The packed-domain
spatial padding is *zero bits*, i.e. -1 in the ±1 domain — exactly what the
paper's fixed-cnum hardware does (cnum_l = FW*FH*FD regardless of border);
the training path pads activations with -1 to match.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels.binary_conv import xnor_gemm
from .kernels.fp_conv import fp_gemm
from .kernels.maxpool import maxpool2x2
from .kernels.norm_binarize import norm_affine, norm_binarize
from .packing import pack_bits_jnp


# ---------------------------------------------------------------------------
# Configuration (paper Table 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One binary conv layer: 3x3 filters, stride 1, 1-pixel zero padding
    (paper §2.5), optionally followed by 2x2/2 max-pool."""

    out_channels: int
    pool: bool


@dataclasses.dataclass(frozen=True)
class BcnnConfig:
    """A BCNN network description (paper Table 2 shape family)."""

    name: str
    conv: tuple[ConvSpec, ...]
    fc: tuple[int, ...]  # hidden FC widths
    classes: int = 10
    input_hw: int = 32
    input_channels: int = 3
    input_bits: int = 6  # paper §3.1: inputs rescaled to [-31, 31]

    @property
    def num_layers(self) -> int:
        return len(self.conv) + len(self.fc) + 1

    def conv_shapes(self) -> list[tuple[int, int, int, int, bool]]:
        """Per conv layer: (in_c, out_c, in_hw, out_hw, pool)."""
        shapes = []
        hw = self.input_hw
        in_c = self.input_channels
        for spec in self.conv:
            out_hw = hw // 2 if spec.pool else hw
            shapes.append((in_c, spec.out_channels, hw, out_hw, spec.pool))
            in_c, hw = spec.out_channels, out_hw
        return shapes

    @property
    def fc_in_features(self) -> int:
        *_, (_, out_c, _, out_hw, _) = self.conv_shapes()
        return out_c * out_hw * out_hw

    def fc_shapes(self) -> list[tuple[int, int]]:
        """Per FC layer (incl. classifier): (in_features, out_features)."""
        dims = [self.fc_in_features, *self.fc, self.classes]
        return list(zip(dims[:-1], dims[1:]))

    def cnum(self, layer: int) -> int:
        """cnum_l = FW*FH*FD, the XNOR count per output value (paper eq. 6).
        ``layer`` is 1-based as in the paper (1 = first conv)."""
        if layer == 1:
            return 9 * self.input_channels
        conv_shapes = self.conv_shapes()
        if layer <= len(conv_shapes):
            return 9 * conv_shapes[layer - 1][0]
        fc_shapes = self.fc_shapes()
        return fc_shapes[layer - len(conv_shapes) - 1][0]

    def ops_per_image(self) -> int:
        """Total MAC-equivalent op count x2 (multiply + add), the paper's
        GOPS accounting (7663 GOPS = ops_per_image * FPS for Table 2)."""
        total = 0
        hw = self.input_hw
        in_c = self.input_channels
        for spec in self.conv:
            total += hw * hw * spec.out_channels * 9 * in_c
            if spec.pool:
                hw //= 2
            in_c = spec.out_channels
        for in_f, out_f in self.fc_shapes():
            total += in_f * out_f
        return 2 * total


TABLE2 = BcnnConfig(
    name="cifar10-table2",
    conv=(
        ConvSpec(128, False),
        ConvSpec(128, True),
        ConvSpec(256, False),
        ConvSpec(256, True),
        ConvSpec(512, False),
        ConvSpec(512, True),
    ),
    fc=(1024, 1024),
)

SMALL = BcnnConfig(
    name="synthetic-small",
    conv=(
        ConvSpec(32, False),
        ConvSpec(32, True),
        ConvSpec(64, False),
        ConvSpec(64, True),
        ConvSpec(128, False),
        ConvSpec(128, True),
    ),
    fc=(256, 256),
)

TINY = BcnnConfig(
    name="tiny-test",
    conv=(ConvSpec(32, True), ConvSpec(32, True)),
    fc=(64,),
    input_hw=16,
)

CONFIGS = {"table2": TABLE2, "small": SMALL, "tiny": TINY}


# ---------------------------------------------------------------------------
# Hardware-path forward (packed, integer) — what the FPGA/Rust engine runs
# ---------------------------------------------------------------------------


def im2col_int(x: jnp.ndarray) -> jnp.ndarray:
    """3x3/pad-1 patch extraction for the integer first layer.

    x: int32 NHWC [B, H, W, C] -> [B*H*W, 9*C] patches in (kh, kw, c) order;
    borders are zero-padded (true zeros: layer-1 inputs are not binary).
    """
    b, h, w, c = x.shape
    p = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    taps = [p[:, dh : dh + h, dw : dw + w, :] for dh in range(3) for dw in range(3)]
    return jnp.concatenate(taps, axis=-1).reshape(b * h * w, 9 * c)


def im2col_packed(a: jnp.ndarray) -> jnp.ndarray:
    """3x3/pad-1 patch extraction in the packed binary domain.

    a: uint32 [B, H, W, CW] -> [B*H*W, 9*CW].  Padding inserts zero words =
    0-bits = -1 activations; cnum stays FW*FH*FD everywhere (paper hardware
    semantics, see module docstring).
    """
    b, h, w, cw = a.shape
    p = jnp.pad(a, ((0, 0), (1, 1), (1, 1), (0, 0)))
    taps = [p[:, dh : dh + h, dw : dw + w, :] for dh in range(3) for dw in range(3)]
    return jnp.concatenate(taps, axis=-1).reshape(b * h * w, 9 * cw)


def forward_packed(params: dict, x: jnp.ndarray, config: BcnnConfig) -> jnp.ndarray:
    """Hardware-path inference (paper fig. 3): int32 NHWC image batch in
    [-31, 31] -> float32 [B, classes] scores.

    ``params`` (see ``train.export_params``):
      w1 int32 [C1, 9*Cin]; c1 int32 [C1];
      w{l} uint32 [Cout, 9*Cin/32]; c{l} int32 [Cout]  (hidden layers);
      w{L} uint32 [classes, in/32]; scale/bias float32 [classes] (output).
    """
    b = x.shape[0]
    conv_shapes = config.conv_shapes()

    # --- layer 1: FpDotProduct + NormBinarize (paper fig. 3 part 1) ---
    in_c, out_c, hw, _, pool = conv_shapes[0]
    patches = im2col_int(x)  # [B*HW^2, 9*Cin]
    y = fp_gemm(patches, params["w1"])  # int32 [B*HW^2, C1]
    if pool:
        y = maxpool2x2(y.reshape(b, hw, hw, out_c))
        hw //= 2
        y = y.reshape(b * hw * hw, out_c)
    bits = norm_binarize(y, params["c1"])
    a = pack_bits_jnp(bits).reshape(b, hw, hw, out_c // 32)

    # --- hidden conv layers: XnorDotProduct [+ MP] + NormBinarize ---
    for idx in range(1, len(conv_shapes)):
        in_c, out_c, hw, out_hw, pool = conv_shapes[idx]
        layer = idx + 1
        patches = im2col_packed(a)  # [B*hw^2, 9*in_c/32]
        y = xnor_gemm(patches, params[f"w{layer}"], k_bits=9 * in_c)
        if pool:
            y = maxpool2x2(y.reshape(b, hw, hw, out_c)).reshape(b * out_hw * out_hw, out_c)
        bits = norm_binarize(y, params[f"c{layer}"])
        a = pack_bits_jnp(bits).reshape(b, out_hw, out_hw, out_c // 32)

    # --- FC layers ---
    a = a.reshape(b, -1)  # packed (h, w, c) flattening
    fc_shapes = config.fc_shapes()
    n_conv = len(conv_shapes)
    for j, (in_f, out_f) in enumerate(fc_shapes):
        layer = n_conv + 1 + j
        y = xnor_gemm(a, params[f"w{layer}"], k_bits=in_f)
        if j < len(fc_shapes) - 1:
            bits = norm_binarize(y, params[f"c{layer}"])
            a = pack_bits_jnp(bits)
        else:
            # output layer: Norm without binarization (paper fig. 3 part 3)
            return norm_affine(y, params["scale"], params["bias"])
    raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# Training-path forward (float, STE) — produces the params to fold
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _binarize_ste_impl(x):
    return jnp.where(x >= 0, 1.0, -1.0)


def _binarize_fwd(x):
    return _binarize_ste_impl(x), x


def _binarize_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


_binarize_ste_impl.defvjp(_binarize_fwd, _binarize_bwd)


def _conv3x3_pm1(a: jnp.ndarray, w: jnp.ndarray, pad_value: float) -> jnp.ndarray:
    """3x3/stride-1 conv via explicit constant padding + VALID conv.

    a: float NHWC [B, H, W, Cin]; w: float [Cout, 9*Cin] in (kh, kw, c)
    patch order (same layout as the packed weights); pad_value -1.0 for
    binary activations (0-bit padding), 0.0 for the integer first layer.
    """
    b, h, wd, cin = a.shape
    p = jnp.pad(a, ((0, 0), (1, 1), (1, 1), (0, 0)), constant_values=pad_value)
    taps = [p[:, dh : dh + h, dw : dw + wd, :] for dh in range(3) for dw in range(3)]
    patches = jnp.concatenate(taps, axis=-1).reshape(b * h * wd, 9 * cin)
    y = patches @ w.T  # [B*H*W, Cout]
    return y.reshape(b, h, wd, -1)


def batchnorm_apply(y, gamma, beta, mean, var, eps=1e-4):
    """Inference-mode batch normalization, paper eq. 2."""
    return (y - mean) / jnp.sqrt(var + eps) * gamma + beta


def init_train_params(config: BcnnConfig, key: jax.Array) -> dict:
    """Real-valued master weights + BN params (BinaryNet training style)."""
    params = {}
    keys = jax.random.split(key, config.num_layers)
    conv_shapes = config.conv_shapes()
    for i, (in_c, out_c, _, _, _) in enumerate(conv_shapes):
        fan_in = 9 * in_c
        params[f"w{i + 1}"] = (
            jax.random.uniform(keys[i], (out_c, fan_in), minval=-1.0, maxval=1.0)
        )
        params[f"bn{i + 1}"] = _bn_init(out_c)
    for j, (in_f, out_f) in enumerate(config.fc_shapes()):
        layer = len(conv_shapes) + 1 + j
        params[f"w{layer}"] = jax.random.uniform(
            keys[layer - 1], (out_f, in_f), minval=-1.0, maxval=1.0
        )
        params[f"bn{layer}"] = _bn_init(out_f)
    return params


def _bn_init(c: int) -> dict:
    return {
        "gamma": jnp.ones((c,)),
        "beta": jnp.zeros((c,)),
        "mean": jnp.zeros((c,)),
        "var": jnp.ones((c,)),
    }


def forward_train(
    params: dict,
    x: jnp.ndarray,
    config: BcnnConfig,
    *,
    train: bool = True,
) -> tuple[jnp.ndarray, dict]:
    """Training-path forward: float [B, H, W, C] input (integer-valued, in
    [-31, 31]) -> (scores [B, classes], batch_stats).

    Semantics match :func:`forward_packed` exactly after threshold folding:
    ±1 weights/activations, -1 padding for binary layers, max-pool on the
    pre-BN integer conv outputs, BN then sign.  In ``train`` mode BN uses
    batch statistics and returns them so the loop can update running stats.
    """
    stats = {}
    conv_shapes = config.conv_shapes()
    a = x.astype(jnp.float32)
    for i, (in_c, out_c, hw, out_hw, pool) in enumerate(conv_shapes):
        layer = i + 1
        wb = _binarize_ste_impl(params[f"w{layer}"])
        pad_value = 0.0 if layer == 1 else -1.0
        y = _conv3x3_pm1(a, wb, pad_value)
        if pool:
            b_, h_, w_, c_ = y.shape
            y = y.reshape(b_, h_ // 2, 2, w_ // 2, 2, c_).max(axis=(2, 4))
        y, stats[f"bn{layer}"] = _bn_forward(y, params[f"bn{layer}"], train)
        a = _binarize_ste_impl(y)

    b_ = a.shape[0]
    a = a.reshape(b_, -1)  # (h, w, c) flattening, matches packed path
    fc_shapes = config.fc_shapes()
    n_conv = len(conv_shapes)
    for j, (in_f, out_f) in enumerate(fc_shapes):
        layer = n_conv + 1 + j
        wb = _binarize_ste_impl(params[f"w{layer}"])
        y = a @ wb.T
        y, stats[f"bn{layer}"] = _bn_forward(y, params[f"bn{layer}"], train)
        if j < len(fc_shapes) - 1:
            a = _binarize_ste_impl(y)
        else:
            return y, stats
    raise AssertionError("unreachable")


def _bn_forward(y, bn, train):
    axes = tuple(range(y.ndim - 1))
    if train:
        mean = jnp.mean(y, axis=axes)
        var = jnp.var(y, axis=axes)
    else:
        mean, var = bn["mean"], bn["var"]
    out = batchnorm_apply(y, bn["gamma"], bn["beta"], mean, var)
    return out, {"mean": jax.lax.stop_gradient(mean), "var": jax.lax.stop_gradient(var)}
