"""BCNN training (BinaryNet-style STE) + threshold folding + export.

The paper deploys the Courbariaux-Bengio BinaryNet CIFAR-10 model; this
module is the substitute training pipeline (DESIGN.md §2): straight-through
estimator training in JAX on the synthetic dataset, then *threshold
folding* (paper §3.2) that collapses batch-norm + binarize + the 1/0
compensation of eq. 6 into one integer threshold ``c_l`` per channel, and
finally export to the ``.bcnn`` interchange file and to jnp params for the
hardware-path graph.

Run as a module (from ``python/``)::

    python -m compile.train --config small --steps 300 --out ../artifacts
    python -m compile.train --config table2 --random --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .export import (
    KIND_BIN_CONV,
    KIND_BIN_FC,
    KIND_BIN_FC_OUT,
    KIND_FP_CONV,
    BcnnFile,
    LayerRecord,
    write_bcnn,
)
from .model import (
    CONFIGS,
    BcnnConfig,
    forward_packed,
    forward_train,
    init_train_params,
)
from .packing import pack_bits_jnp

BN_EPS = 1e-4
GAMMA_MIN = 0.05


# ---------------------------------------------------------------------------
# Threshold folding (paper §3.2)
# ---------------------------------------------------------------------------


def fold_params(train_params: dict, config: BcnnConfig) -> list[LayerRecord]:
    """Fold trained float params into hardware layer records.

    For hidden layers the BN-then-sign condition ``gamma*(y_lo-mu)/sigma'
    + beta >= 0`` (gamma > 0 enforced in training) becomes ``y_lo >= t``
    with ``t = mu - beta*sigma'/gamma``; with the 1/0 encoding
    ``y_lo = 2*y_l - cnum`` (eq. 6) this is ``y_l >= c_l``,
    ``c_l = ceil((t + cnum)/2)`` — exact for integer ``y_l`` (the paper
    rounds to nearest; ceil preserves the comparison exactly).
    """
    records: list[LayerRecord] = []
    conv_shapes = config.conv_shapes()
    n_conv = len(conv_shapes)
    fc_shapes = config.fc_shapes()

    def bn_threshold(layer: int) -> np.ndarray:
        bn = train_params[f"bn{layer}"]
        gamma = np.asarray(bn["gamma"], np.float64)
        if np.any(gamma <= 0):
            raise ValueError(f"layer {layer}: gamma must be positive after training")
        sigma = np.sqrt(np.asarray(bn["var"], np.float64) + BN_EPS)
        return np.asarray(bn["mean"], np.float64) - np.asarray(
            bn["beta"], np.float64
        ) * sigma / gamma

    for i, (in_c, out_c, _, _, pool) in enumerate(conv_shapes):
        layer = i + 1
        w_sign = np.where(np.asarray(train_params[f"w{layer}"]) >= 0, 1, -1)
        t = bn_threshold(layer)
        if layer == 1:
            records.append(
                LayerRecord(
                    kind=KIND_FP_CONV,
                    in_dim=in_c,
                    out_dim=out_c,
                    pool=pool,
                    weights_i8=w_sign.astype(np.int8),
                    thresholds=np.ceil(t).astype(np.int32),
                )
            )
        else:
            cnum = 9 * in_c
            records.append(
                LayerRecord(
                    kind=KIND_BIN_CONV,
                    in_dim=in_c,
                    out_dim=out_c,
                    pool=pool,
                    weights_bits=(w_sign > 0).astype(np.int32),
                    thresholds=np.ceil((t + cnum) / 2.0).astype(np.int32),
                )
            )

    for j, (in_f, out_f) in enumerate(fc_shapes):
        layer = n_conv + 1 + j
        w_sign = np.where(np.asarray(train_params[f"w{layer}"]) >= 0, 1, -1)
        bits = (w_sign > 0).astype(np.int32)
        if j < len(fc_shapes) - 1:
            t = bn_threshold(layer)
            records.append(
                LayerRecord(
                    kind=KIND_BIN_FC,
                    in_dim=in_f,
                    out_dim=out_f,
                    weights_bits=bits,
                    thresholds=np.ceil((t + in_f) / 2.0).astype(np.int32),
                )
            )
        else:
            bn = train_params[f"bn{layer}"]
            gamma = np.asarray(bn["gamma"], np.float64)
            sigma = np.sqrt(np.asarray(bn["var"], np.float64) + BN_EPS)
            mean = np.asarray(bn["mean"], np.float64)
            beta = np.asarray(bn["beta"], np.float64)
            # score = gamma*(2y - cnum - mu)/sigma' + beta = scale*y + bias
            records.append(
                LayerRecord(
                    kind=KIND_BIN_FC_OUT,
                    in_dim=in_f,
                    out_dim=out_f,
                    weights_bits=bits,
                    scale=(2.0 * gamma / sigma).astype(np.float32),
                    bias=(beta - gamma * (mean + in_f) / sigma).astype(np.float32),
                )
            )
    return records


def records_to_jnp_params(records: list[LayerRecord]) -> dict:
    """Layer records -> the params dict :func:`compile.model.forward_packed`
    expects (uint32-packed weights for the Pallas kernels)."""
    params: dict = {}
    for idx, rec in enumerate(records):
        layer = idx + 1
        if rec.kind == KIND_FP_CONV:
            params[f"w{layer}"] = jnp.asarray(rec.weights_i8, jnp.int32)
            params[f"c{layer}"] = jnp.asarray(rec.thresholds, jnp.int32)
        elif rec.kind in (KIND_BIN_CONV, KIND_BIN_FC):
            bits = np.asarray(rec.weights_bits)
            k = bits.shape[1]
            pad = (-k) % 32
            if pad:
                bits = np.pad(bits, ((0, 0), (0, pad)))
            params[f"w{layer}"] = pack_bits_jnp(jnp.asarray(bits))
            params[f"c{layer}"] = jnp.asarray(rec.thresholds, jnp.int32)
        else:
            bits = np.asarray(rec.weights_bits)
            k = bits.shape[1]
            pad = (-k) % 32
            if pad:
                bits = np.pad(bits, ((0, 0), (0, pad)))
            params[f"w{layer}"] = pack_bits_jnp(jnp.asarray(bits))
            params["scale"] = jnp.asarray(rec.scale, jnp.float32)
            params["bias"] = jnp.asarray(rec.bias, jnp.float32)
    return params


def records_to_bcnn(records: list[LayerRecord], config: BcnnConfig, name: str) -> BcnnFile:
    return BcnnFile(
        name=name,
        input_hw=config.input_hw,
        input_channels=config.input_channels,
        input_bits=config.input_bits,
        classes=config.classes,
        layers=records,
    )


def random_records(config: BcnnConfig, seed: int = 0) -> list[LayerRecord]:
    """Random ±1 weights with *balanced* thresholds (c_l ~ cnum/2 + jitter,
    so roughly half the output bits fire).  Used for the full Table-2 model
    where timing/architecture experiments don't need trained weights."""
    rng = np.random.default_rng(seed)
    records: list[LayerRecord] = []
    conv_shapes = config.conv_shapes()
    for i, (in_c, out_c, _, _, pool) in enumerate(conv_shapes):
        if i == 0:
            records.append(
                LayerRecord(
                    kind=KIND_FP_CONV,
                    in_dim=in_c,
                    out_dim=out_c,
                    pool=pool,
                    weights_i8=(rng.integers(0, 2, (out_c, 9 * in_c)) * 2 - 1).astype(
                        np.int8
                    ),
                    thresholds=rng.integers(-40, 40, out_c).astype(np.int32),
                )
            )
        else:
            cnum = 9 * in_c
            jitter = rng.integers(-cnum // 16 - 1, cnum // 16 + 2, out_c)
            records.append(
                LayerRecord(
                    kind=KIND_BIN_CONV,
                    in_dim=in_c,
                    out_dim=out_c,
                    pool=pool,
                    weights_bits=rng.integers(0, 2, (out_c, 9 * in_c)).astype(np.int32),
                    thresholds=(cnum // 2 + jitter).astype(np.int32),
                )
            )
    fc_shapes = config.fc_shapes()
    for j, (in_f, out_f) in enumerate(fc_shapes):
        bits = rng.integers(0, 2, (out_f, in_f)).astype(np.int32)
        if j < len(fc_shapes) - 1:
            jitter = rng.integers(-in_f // 32 - 1, in_f // 32 + 2, out_f)
            records.append(
                LayerRecord(
                    kind=KIND_BIN_FC,
                    in_dim=in_f,
                    out_dim=out_f,
                    weights_bits=bits,
                    thresholds=(in_f // 2 + jitter).astype(np.int32),
                )
            )
        else:
            records.append(
                LayerRecord(
                    kind=KIND_BIN_FC_OUT,
                    in_dim=in_f,
                    out_dim=out_f,
                    weights_bits=bits,
                    scale=np.full(out_f, 2.0 / np.sqrt(in_f), np.float32),
                    bias=rng.normal(0, 0.5, out_f).astype(np.float32),
                )
            )
    return records


# ---------------------------------------------------------------------------
# Training loop (manual Adam, BinaryNet-style constraints)
# ---------------------------------------------------------------------------


def _adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def _adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    scale = lr * jnp.sqrt(1 - b2**t) / (1 - b1**t)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - scale * m_ / (jnp.sqrt(v_) + eps), params, m, v
    )
    return new, {"m": m, "v": v, "t": t}


def _apply_constraints(params: dict, config: BcnnConfig) -> dict:
    """BinaryNet weight clipping to [-1, 1] and gamma > 0 (needed for the
    direction of the folded threshold compare, paper §3.2)."""
    out = dict(params)
    for l in range(1, config.num_layers + 1):
        out[f"w{l}"] = jnp.clip(params[f"w{l}"], -1.0, 1.0)
        bn = dict(params[f"bn{l}"])
        bn["gamma"] = jnp.maximum(bn["gamma"], GAMMA_MIN)
        out[f"bn{l}"] = bn
    return out


def make_train_step(config: BcnnConfig, lr: float, momentum: float = 0.9):
    def loss_fn(params, x, y):
        scores, stats = forward_train(params, x, config, train=True)
        logp = jax.nn.log_softmax(scores)
        loss = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        acc = (jnp.argmax(scores, axis=1) == y).mean()
        return loss, (stats, acc)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, (stats, acc)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, x, y
        )
        params, opt_state = _adam_update(params, grads, opt_state, lr)
        params = _apply_constraints(params, config)
        # update BN running stats from batch stats
        for name, st in stats.items():
            bn = dict(params[name])
            bn["mean"] = momentum * bn["mean"] + (1 - momentum) * st["mean"]
            bn["var"] = momentum * bn["var"] + (1 - momentum) * st["var"]
            params[name] = bn
        return params, opt_state, loss, acc

    return step


def evaluate_train_path(params, x, y, config, batch: int = 256) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        scores, _ = forward_train(
            params, jnp.asarray(x[i : i + batch], jnp.float32), config, train=False
        )
        correct += int((jnp.argmax(scores, axis=1) == jnp.asarray(y[i : i + batch])).sum())
    return correct / len(x)


def evaluate_packed_path(jnp_params, x, y, config, batch: int = 64) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        scores = forward_packed(jnp_params, jnp.asarray(x[i : i + batch]), config)
        correct += int((jnp.argmax(scores, axis=1) == jnp.asarray(y[i : i + batch])).sum())
    return correct / len(x)


def train(
    config: BcnnConfig,
    *,
    steps: int,
    batch: int,
    n_train: int,
    n_test: int,
    lr: float,
    seed: int,
    log_path: Path | None = None,
) -> tuple[dict, dict]:
    """Train and return (train_params, metrics)."""
    x_tr, y_tr, x_te, y_te = data_mod.make_dataset(
        n_train,
        n_test,
        classes=config.classes,
        hw=config.input_hw,
        channels=config.input_channels,
        seed=seed,
    )
    params = init_train_params(config, jax.random.PRNGKey(seed))
    opt_state = _adam_init(params)
    step_fn = make_train_step(config, lr)
    rng = np.random.default_rng(seed + 1)
    log_rows = ["step,loss,batch_acc,elapsed_s"]
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n_train, batch)
        params, opt_state, loss, acc = step_fn(
            params, opt_state, jnp.asarray(x_tr[idx], jnp.float32), jnp.asarray(y_tr[idx])
        )
        if s % 10 == 0 or s == steps - 1:
            row = f"{s},{float(loss):.4f},{float(acc):.4f},{time.time() - t0:.1f}"
            log_rows.append(row)
            print(f"[train] {row}", flush=True)
    test_acc = evaluate_train_path(params, x_te, y_te, config)
    metrics = {
        "steps": steps,
        "train_time_s": round(time.time() - t0, 1),
        "test_acc_train_path": test_acc,
    }
    if log_path is not None:
        log_path.write_text("\n".join(log_rows) + "\n")
    return params, metrics


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", choices=sorted(CONFIGS), default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=50)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--n-test", type=int, default=500)
    ap.add_argument("--lr", type=float, default=0.005)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--random", action="store_true", help="export random weights, no training")
    ap.add_argument("--out", type=Path, default=Path("../artifacts"))
    args = ap.parse_args(argv)

    config = CONFIGS[args.config]
    args.out.mkdir(parents=True, exist_ok=True)
    stem = f"model_{args.config}"

    if args.random:
        records = random_records(config, args.seed)
        metrics = {"mode": "random", "seed": args.seed}
    else:
        params, metrics = train(
            config,
            steps=args.steps,
            batch=args.batch,
            n_train=args.n_train,
            n_test=args.n_test,
            lr=args.lr,
            seed=args.seed,
            log_path=args.out / f"train_log_{args.config}.csv",
        )
        records = fold_params(params, config)
        metrics["mode"] = "trained"
        # verify the folded hardware path agrees with the training path
        x_tr, y_tr, x_te, y_te = data_mod.make_dataset(
            64,
            args.n_test,
            classes=config.classes,
            hw=config.input_hw,
            channels=config.input_channels,
            seed=args.seed,
        )
        jnp_params = records_to_jnp_params(records)
        metrics["test_acc_packed_path"] = evaluate_packed_path(
            jnp_params, x_te, y_te, config
        )
        print(f"[train] test acc (train path)  = {metrics['test_acc_train_path']:.4f}")
        print(f"[train] test acc (packed path) = {metrics['test_acc_packed_path']:.4f}")

    path = args.out / f"{stem}.bcnn"
    write_bcnn(path, records_to_bcnn(records, config, config.name))
    (args.out / f"{stem}.json").write_text(json.dumps(metrics, indent=2) + "\n")
    print(f"[train] wrote {path} ({path.stat().st_size} bytes)")

    # export a labelled test set for the rust end-to-end example
    # (format: b"BSET", u32 n, hw, channels, classes; then per sample
    #  hw*hw*channels int8 pixels + 1 uint8 label)
    _, _, x_te, y_te = data_mod.make_dataset(
        1,
        256,
        classes=config.classes,
        hw=config.input_hw,
        channels=config.input_channels,
        seed=args.seed,
    )
    ts_path = args.out / f"testset_{args.config}.bin"
    import struct

    with open(ts_path, "wb") as f:
        f.write(b"BSET")
        f.write(
            struct.pack(
                "<IIII", len(x_te), config.input_hw, config.input_channels, config.classes
            )
        )
        for img, label in zip(x_te, y_te):
            f.write(img.astype(np.int8).tobytes())
            f.write(struct.pack("<B", int(label)))
    print(f"[train] wrote {ts_path}")


if __name__ == "__main__":
    main()
