"""Bit-packing utilities for the binary-encoded BCNN (paper §3.1).

The paper encodes +1/-1 as 1/0 so that a binary activation/weight costs a
single bit and convolution becomes XNOR + popcount.  On the JAX/Pallas side
we pack 32 binary channels into one ``uint32`` lane (the same packing the
paper's CUDA XNOR kernel uses); the exported ``.bcnn`` model file packs into
``uint64`` words for the Rust engine.

Bit order convention (shared with ``rust/src/bcnn/tensor.rs``): bit ``b`` of
word ``w`` holds flattened element ``w * LANE + b`` (LSB-first).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

LANE32 = 32
LANE64 = 64


def pack_bits_jnp(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a {0,1} int array of shape [..., K] (K % 32 == 0) into uint32
    words of shape [..., K // 32], LSB-first."""
    k = bits.shape[-1]
    if k % LANE32 != 0:
        raise ValueError(f"last dim {k} not a multiple of {LANE32}")
    b = bits.reshape(bits.shape[:-1] + (k // LANE32, LANE32)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(LANE32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits_jnp(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits_jnp`: uint32 words [..., K//32] -> {0,1}
    int32 array [..., K]."""
    if k % LANE32 != 0:
        raise ValueError(f"k={k} not a multiple of {LANE32}")
    shifts = jnp.arange(LANE32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (k,)).astype(jnp.int32)


def pack_bits_np64(bits: np.ndarray) -> np.ndarray:
    """Pack a {0,1} array [..., K] into uint64 words [..., ceil(K/64)],
    LSB-first, zero-padding the tail.  Used by the ``.bcnn`` exporter."""
    k = bits.shape[-1]
    kw = (k + LANE64 - 1) // LANE64
    padded = np.zeros(bits.shape[:-1] + (kw * LANE64,), dtype=np.uint64)
    padded[..., :k] = bits.astype(np.uint64)
    padded = padded.reshape(bits.shape[:-1] + (kw, LANE64))
    weights = (np.uint64(1) << np.arange(LANE64, dtype=np.uint64))
    return (padded * weights).sum(axis=-1, dtype=np.uint64)


def unpack_bits_np64(words: np.ndarray, k: int) -> np.ndarray:
    """Inverse of :func:`pack_bits_np64` -> {0,1} int32 array [..., K]."""
    shifts = np.arange(LANE64, dtype=np.uint64)
    bits = (words[..., None] >> shifts) & np.uint64(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * LANE64,))
    return flat[..., :k].astype(np.int32)


def pm1_to_bits(x) -> np.ndarray:
    """Map the paper's +1/-1 domain onto the 1/0 encoding (§3.1)."""
    x = np.asarray(x)
    return (x > 0).astype(np.int32)


def bits_to_pm1(b) -> np.ndarray:
    """Inverse map: 1/0 -> +1/-1."""
    b = np.asarray(b)
    return (2 * b - 1).astype(np.int32)
