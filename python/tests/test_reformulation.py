"""Proofs (by exhaustive/property test) of the paper's §3 reformulation.

The paper's hardware never computes the ±1 convolution of eq. (3); it
computes the 1/0 match count of eq. (5) and compensates in the threshold
(eq. 6, 8).  These tests pin the algebra the whole stack rests on.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import conv_pm1_ref, norm_binarize_ref, xnor_gemm_ref
from compile.packing import bits_to_pm1, pack_bits_jnp, pm1_to_bits

SETTINGS = dict(max_examples=40, deadline=None)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 16),
    n=st.integers(1, 16),
    kw=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_eq6_compensation_exact(m, n, kw, seed):
    """y_lo = 2*y_l - cnum (paper eq. 6), for all inputs."""
    rng = np.random.default_rng(seed)
    k = kw * 32
    a_bits = rng.integers(0, 2, (m, k))
    w_bits = rng.integers(0, 2, (n, k))
    y_l = np.asarray(
        xnor_gemm_ref(
            pack_bits_jnp(jnp.asarray(a_bits)), pack_bits_jnp(jnp.asarray(w_bits)), k
        )
    )
    y_lo = np.asarray(
        conv_pm1_ref(jnp.asarray(bits_to_pm1(a_bits)), jnp.asarray(bits_to_pm1(w_bits)))
    )
    assert np.array_equal(y_lo, 2 * y_l - k)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 32))
def test_eq8_threshold_equals_bn_sign(seed, n):
    """NormBinarize(y_l, c_l) == Binarize(BN(y_lo)) with
    c_l = ceil((cnum + mu - beta*sigma'/gamma) / 2) — the paper §3.2 fold
    (ceil instead of round-to-nearest keeps the compare exact for integer
    y_l; ties BN(y_lo) == 0 binarize to 1 per eq. 4)."""
    rng = np.random.default_rng(seed)
    cnum = int(rng.integers(8, 512))
    m = 64
    y_l = rng.integers(0, cnum + 1, (m, n))
    y_lo = 2 * y_l - cnum
    gamma = rng.uniform(0.05, 2.0, n)
    beta = rng.normal(0, 1.0, n)
    mu = rng.normal(0, cnum / 4, n)
    var = rng.uniform(0.5, cnum, n)
    eps = 1e-4
    sigma = np.sqrt(var + eps)
    # software path: batch-norm then sign
    z = (y_lo - mu) / sigma * gamma + beta
    soft = (z >= 0).astype(np.int32)
    # hardware path: integer threshold compare
    t = mu - beta * sigma / gamma
    c = np.ceil((t + cnum) / 2.0).astype(np.int64)
    hard = np.asarray(
        norm_binarize_ref(jnp.asarray(y_l, jnp.int32), jnp.asarray(c, jnp.int32))
    )
    # exclude razor-thin float ties (|z| ~ 0), measure-zero for trained nets
    safe = np.abs(z) > 1e-9
    assert np.array_equal(hard[safe], soft[safe])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_pm1_bit_encoding_roundtrip(seed):
    rng = np.random.default_rng(seed)
    v = rng.choice([-1, 1], 257)
    assert np.array_equal(bits_to_pm1(pm1_to_bits(v)), v)


def test_padding_is_minus_one():
    """Packed-domain zero-padding = 0 bits = -1 activations: a padded tap
    against weight bit w contributes XNOR(0, w) = 1-w matches, i.e. the ±1
    product (-1)*(2w-1).  Exhaustive over the bit."""
    for w_bit in (0, 1):
        xnor = 1 - (0 ^ w_bit)
        pm1_product = (-1) * (2 * w_bit - 1)
        assert 2 * xnor - 1 == pm1_product
