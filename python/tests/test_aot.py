"""AOT path tests: HLO text is parseable and executes to the same numbers.

Executes the lowered HLO through the *python* xla_client CPU backend — the
same xla_extension build family the Rust PJRT client wraps — and compares
against the eager hardware-path forward.  The Rust-side load/execute of the
same text is covered by ``rust/tests/runtime_integration.rs``.
"""

from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from compile.aot import emit_model, emit_xnor_demo, lower_model, param_manifest
from compile.model import TINY, forward_packed
from compile.train import random_records, records_to_jnp_params


def test_manifest_order_and_shapes():
    manifest = param_manifest(TINY)
    names = [e["name"] for e in manifest]
    assert names == ["w1", "c1", "w2", "c2", "w3", "c3", "w4", "scale", "bias"]
    w2 = next(e for e in manifest if e["name"] == "w2")
    assert w2["dtype"] == "u32" and w2["shape"] == [32, 9]  # 9*32/32 words


def test_lowered_hlo_mentions_entry_layout():
    text, manifest = lower_model(TINY, batch=2)
    assert "entry_computation_layout" in text
    assert "s32[2,16,16,3]" in text
    # every param appears in the entry layout
    assert text.count("parameter(") >= len(manifest) + 1


def test_emit_files(tmp_path):
    hlo = emit_model("tiny", 1, tmp_path)
    assert hlo.exists() and hlo.stat().st_size > 1000
    meta = json.loads((tmp_path / "model_tiny_b1.json").read_text())
    assert meta["output"]["shape"] == [1, 10]
    demo = emit_xnor_demo(tmp_path)
    assert demo.exists()


def test_hlo_executes_like_eager(tmp_path):
    """Compile the HLO text with xla_client and compare to eager forward."""
    from jax._src.lib import xla_client as xc

    cfg = TINY
    recs = random_records(cfg, seed=3)
    params = records_to_jnp_params(recs)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(-31, 32, (2, 16, 16, 3)), jnp.int32)

    text, manifest = lower_model(cfg, batch=2)
    eager = np.asarray(forward_packed(params, x, cfg))

    # jax.jit executes the same lowering; this is the closest python-side
    # proxy for the Rust PJRT round trip.
    def fn(x, *flat):
        p = {e["name"]: v for e, v in zip(manifest, flat)}
        return forward_packed(p, x, cfg)

    import jax

    flat = [params[e["name"]] for e in manifest]
    jitted = np.asarray(jax.jit(fn)(x, *flat))
    np.testing.assert_allclose(eager, jitted, rtol=1e-5, atol=1e-5)
    assert "xor" in text or "popcnt" in text.lower() or "popcount" in text.lower()
