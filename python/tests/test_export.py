"""Round-trip tests for the ``.bcnn`` interchange format."""

from __future__ import annotations

import numpy as np
import pytest

from compile.export import (
    KIND_BIN_CONV,
    KIND_BIN_FC,
    KIND_BIN_FC_OUT,
    KIND_FP_CONV,
    read_bcnn,
    write_bcnn,
)
from compile.model import CONFIGS, TINY
from compile.train import random_records, records_to_bcnn


@pytest.mark.parametrize("name", ["tiny", "small"])
def test_roundtrip(tmp_path, name):
    cfg = CONFIGS[name]
    recs = random_records(cfg, seed=9)
    path = tmp_path / "m.bcnn"
    write_bcnn(path, records_to_bcnn(recs, cfg, cfg.name))
    back = read_bcnn(path)
    assert back.name == cfg.name
    assert back.input_hw == cfg.input_hw
    assert back.classes == cfg.classes
    assert len(back.layers) == len(recs)
    for got, want in zip(back.layers, recs):
        assert got.kind == want.kind
        assert got.in_dim == want.in_dim
        assert got.out_dim == want.out_dim
        assert got.pool == want.pool
        if want.kind == KIND_FP_CONV:
            assert np.array_equal(got.weights_i8, want.weights_i8)
        else:
            assert np.array_equal(got.weights_bits, want.weights_bits)
        if want.kind == KIND_BIN_FC_OUT:
            np.testing.assert_allclose(got.scale, want.scale)
            np.testing.assert_allclose(got.bias, want.bias)
        else:
            assert np.array_equal(got.thresholds, want.thresholds)


def test_layer_kind_sequence(tmp_path):
    recs = random_records(TINY, seed=0)
    kinds = [r.kind for r in recs]
    assert kinds[0] == KIND_FP_CONV
    assert all(k == KIND_BIN_CONV for k in kinds[1 : len(TINY.conv)])
    assert all(k == KIND_BIN_FC for k in kinds[len(TINY.conv) : -1])
    assert kinds[-1] == KIND_BIN_FC_OUT


def test_truncated_file_rejected(tmp_path):
    recs = random_records(TINY, seed=1)
    path = tmp_path / "m.bcnn"
    write_bcnn(path, records_to_bcnn(recs, TINY, "t"))
    data = path.read_bytes()
    bad = tmp_path / "bad.bcnn"
    bad.write_bytes(data[: len(data) // 2])
    with pytest.raises(ValueError):
        read_bcnn(bad)


def test_bad_magic_rejected(tmp_path):
    bad = tmp_path / "bad.bcnn"
    bad.write_bytes(b"NOPE" + b"\0" * 64)
    with pytest.raises(ValueError, match="magic"):
        read_bcnn(bad)


def test_trailing_bytes_rejected(tmp_path):
    recs = random_records(TINY, seed=2)
    path = tmp_path / "m.bcnn"
    write_bcnn(path, records_to_bcnn(recs, TINY, "t"))
    bad = tmp_path / "bad.bcnn"
    bad.write_bytes(path.read_bytes() + b"\x00")
    with pytest.raises(ValueError, match="trailing"):
        read_bcnn(bad)
