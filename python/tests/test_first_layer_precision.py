"""First-layer input-precision sweep (paper §3.1).

The paper rescales inputs to 6-bit signed ([-31, 31]) and reports <0.5%
accuracy loss.  These tests characterize the quantization step itself:
re-quantizing the synthetic dataset to n bits and measuring prediction
churn on a trained tiny model — monotone in precision, negligible at 6
bits, which is the evidence behind the paper's design choice.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as data_mod
from compile.model import TINY, forward_packed
from compile.train import fold_params, records_to_jnp_params, train


@pytest.fixture(scope="module")
def trained():
    params, metrics = train(
        TINY, steps=40, batch=32, n_train=256, n_test=64, lr=0.01, seed=3
    )
    recs = fold_params(params, TINY)
    return records_to_jnp_params(recs), metrics


def requantize(x: np.ndarray, bits: int) -> np.ndarray:
    """Re-quantize 6-bit inputs to `bits` (1..6) signed levels."""
    hi = 2 ** (bits - 1) - 1
    scaled = np.rint(x / 31.0 * hi)
    return (scaled / max(hi, 1) * 31.0).astype(np.int32)


def _preds(params, x):
    scores = forward_packed(params, jnp.asarray(x), TINY)
    return np.argmax(np.asarray(scores), axis=1)


def test_six_bit_is_nearly_lossless(trained):
    params, _ = trained
    _, _, x_te, _ = data_mod.make_dataset(1, 128, hw=TINY.input_hw, seed=3)
    base = _preds(params, x_te)
    q6 = _preds(params, requantize(x_te, 6))
    agreement = (base == q6).mean()
    assert agreement > 0.98, f"6-bit requantization churned {1 - agreement:.2%}"


def test_precision_monotone_trend(trained):
    """Prediction agreement with the 6-bit reference should not improve as
    precision drops (allowing small non-monotonic noise)."""
    params, _ = trained
    _, _, x_te, _ = data_mod.make_dataset(1, 128, hw=TINY.input_hw, seed=3)
    base = _preds(params, x_te)
    agreements = []
    for bits in [6, 4, 2, 1]:
        preds = _preds(params, requantize(x_te, bits))
        agreements.append((base == preds).mean())
    for hi, lo in zip(agreements, agreements[1:]):
        assert lo <= hi + 0.05, f"agreement not monotone: {agreements}"
    # 1-bit input should hurt visibly relative to 6-bit
    assert agreements[-1] < agreements[0] + 1e-9


def test_input_range_clamped():
    """The dataset generator must respect the 6-bit envelope the hardware
    assumes (values outside [-31, 31] would overflow the paper's layer-1
    datapath assumptions)."""
    x, _, _, _ = data_mod.make_dataset(64, 1, seed=9)
    assert x.min() >= -31 and x.max() <= 31
