"""L2 model tests: hardware path vs training path, shapes, config algebra."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as data_mod
from compile.model import (
    CONFIGS,
    SMALL,
    TABLE2,
    TINY,
    forward_packed,
    forward_train,
    im2col_int,
    im2col_packed,
    init_train_params,
)
from compile.train import fold_params, random_records, records_to_jnp_params


def test_table2_matches_paper():
    """Table 2 of the paper, exactly."""
    shapes = TABLE2.conv_shapes()
    assert [(s[0], s[1]) for s in shapes] == [
        (3, 128),
        (128, 128),
        (128, 256),
        (256, 256),
        (256, 512),
        (512, 512),
    ]
    assert [s[3] for s in shapes] == [32, 16, 16, 8, 8, 4]  # output hw
    assert TABLE2.fc_shapes() == [(8192, 1024), (1024, 1024), (1024, 10)]
    assert TABLE2.num_layers == 9


def test_table2_cnum():
    """cnum_l = FW*FH*FD (paper eq. 6)."""
    assert TABLE2.cnum(1) == 27
    assert TABLE2.cnum(2) == 9 * 128
    assert TABLE2.cnum(6) == 9 * 512
    assert TABLE2.cnum(7) == 8192
    assert TABLE2.cnum(9) == 1024


def test_table2_ops_per_image():
    """The paper's 7663-GOPS figure implies ~1.23 GOP/image at 6218 FPS."""
    ops = TABLE2.ops_per_image()
    assert ops == 2 * (
        32 * 32 * 128 * 27
        + 32 * 32 * 128 * 9 * 128
        + 16 * 16 * 256 * 9 * 128
        + 16 * 16 * 256 * 9 * 256
        + 8 * 8 * 512 * 9 * 256
        + 8 * 8 * 512 * 9 * 512
        + 8192 * 1024
        + 1024 * 1024
        + 1024 * 10
    )
    assert abs(ops * 6218 / 1e9 - 7663) / 7663 < 0.02


@pytest.mark.parametrize("name", ["tiny", "small"])
def test_forward_packed_shapes(name):
    cfg = CONFIGS[name]
    recs = random_records(cfg, seed=1)
    params = records_to_jnp_params(recs)
    x = jnp.zeros((2, cfg.input_hw, cfg.input_hw, cfg.input_channels), jnp.int32)
    scores = forward_packed(params, x, cfg)
    assert scores.shape == (2, cfg.classes)
    assert scores.dtype == jnp.float32


def test_im2col_int_center_pixel():
    """The (1,1) tap of the patch at pixel (i,j) is the pixel itself."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-31, 32, (1, 4, 4, 3)), jnp.int32)
    patches = np.asarray(im2col_int(x)).reshape(4, 4, 9, 3)
    assert np.array_equal(patches[:, :, 4, :], np.asarray(x)[0])


def test_im2col_int_zero_border():
    """Corner patch: taps outside the image are zero."""
    x = jnp.ones((1, 4, 4, 1), jnp.int32)
    patches = np.asarray(im2col_int(x)).reshape(4, 4, 9)
    # pixel (0,0): taps (0..2, 0..2) centred there; kh=0 row and kw=0 col pad
    assert patches[0, 0, 0] == 0 and patches[0, 0, 1] == 0 and patches[0, 0, 3] == 0
    assert patches[0, 0, 4] == 1


def test_im2col_packed_matches_int_path():
    """Packed im2col == pack(im2col of unpacked bits with 0-padding)."""
    from compile.packing import pack_bits_jnp, unpack_bits_jnp

    rng = np.random.default_rng(1)
    b, h, c = 2, 4, 32
    bits = rng.integers(0, 2, (b, h, h, c))
    a = pack_bits_jnp(jnp.asarray(bits))
    got = np.asarray(im2col_packed(a))
    # reference: pad bit tensor, gather patches, pack
    p = np.pad(bits, ((0, 0), (1, 1), (1, 1), (0, 0)))
    taps = [p[:, dh : dh + h, dw : dw + h, :] for dh in range(3) for dw in range(3)]
    ref_bits = np.concatenate(taps, axis=-1).reshape(b * h * h, 9 * c)
    want = np.asarray(pack_bits_jnp(jnp.asarray(ref_bits)))
    assert np.array_equal(got, want)


def test_train_and_packed_paths_agree_tiny():
    """After threshold folding, the integer hardware path reproduces the
    float training path's scores (to float tolerance) and predictions."""
    cfg = TINY
    params = init_train_params(cfg, jax.random.PRNGKey(2))
    # jitter BN stats away from defaults so thresholds are non-trivial
    for l in range(1, cfg.num_layers + 1):
        bn = dict(params[f"bn{l}"])
        key = jax.random.PRNGKey(100 + l)
        k1, k2 = jax.random.split(key)
        bn["mean"] = jax.random.normal(k1, bn["mean"].shape) * 3.0
        bn["var"] = jnp.abs(jax.random.normal(k2, bn["var"].shape)) * 5.0 + 0.5
        params[f"bn{l}"] = bn
    x, _, _, _ = data_mod.make_dataset(16, 1, hw=cfg.input_hw, seed=3)
    s_train, _ = forward_train(params, jnp.asarray(x, jnp.float32), cfg, train=False)
    recs = fold_params(params, cfg)
    s_packed = forward_packed(records_to_jnp_params(recs), jnp.asarray(x), cfg)
    np.testing.assert_allclose(
        np.asarray(s_train), np.asarray(s_packed), rtol=1e-4, atol=1e-4
    )


def test_batch_invariance():
    """forward_packed on a batch equals per-image forward (no cross-batch
    leakage — required for the coordinator's dynamic batching)."""
    cfg = TINY
    recs = random_records(cfg, seed=5)
    params = records_to_jnp_params(recs)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.integers(-31, 32, (4, cfg.input_hw, cfg.input_hw, 3)), jnp.int32)
    full = np.asarray(forward_packed(params, x, cfg))
    singles = np.concatenate(
        [np.asarray(forward_packed(params, x[i : i + 1], cfg)) for i in range(4)]
    )
    np.testing.assert_allclose(full, singles, rtol=1e-5, atol=1e-5)


def test_fold_rejects_nonpositive_gamma():
    cfg = TINY
    params = init_train_params(cfg, jax.random.PRNGKey(0))
    bn = dict(params["bn1"])
    bn["gamma"] = bn["gamma"].at[0].set(-1.0)
    params["bn1"] = bn
    with pytest.raises(ValueError, match="gamma"):
        fold_params(params, cfg)
