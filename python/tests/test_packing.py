"""Property tests for the bit-packing layer shared with Rust."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.packing import (
    pack_bits_jnp,
    pack_bits_np64,
    unpack_bits_jnp,
    unpack_bits_np64,
)

SETTINGS = dict(max_examples=50, deadline=None)


@settings(**SETTINGS)
@given(m=st.integers(1, 8), kw=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_jnp_roundtrip(m, kw, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (m, kw * 32))
    packed = pack_bits_jnp(jnp.asarray(bits))
    assert packed.dtype == jnp.uint32
    back = np.asarray(unpack_bits_jnp(packed, kw * 32))
    assert np.array_equal(back, bits)


@settings(**SETTINGS)
@given(m=st.integers(1, 5), k=st.integers(1, 200), seed=st.integers(0, 2**31 - 1))
def test_np64_roundtrip_any_k(m, k, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (m, k))
    packed = pack_bits_np64(bits)
    assert packed.shape == (m, (k + 63) // 64)
    assert np.array_equal(unpack_bits_np64(packed, k), bits)


@settings(**SETTINGS)
@given(kw=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_u32_and_u64_packings_agree(kw, seed):
    """The uint32 (jax-side) and uint64 (.bcnn-side) packings describe the
    same bit string: u64 word w == u32[2w] | u32[2w+1] << 32."""
    rng = np.random.default_rng(seed)
    k = kw * 64
    bits = rng.integers(0, 2, (3, k))
    p32 = np.asarray(pack_bits_jnp(jnp.asarray(bits))).astype(np.uint64)
    p64 = pack_bits_np64(bits)
    lo = p32[:, 0::2]
    hi = p32[:, 1::2]
    assert np.array_equal(p64, lo | (hi << np.uint64(32)))


def test_lsb_first():
    """Bit 0 of word 0 is element 0."""
    bits = np.zeros((1, 32), np.int32)
    bits[0, 0] = 1
    assert int(np.asarray(pack_bits_jnp(jnp.asarray(bits)))[0, 0]) == 1
    bits[0, 0] = 0
    bits[0, 31] = 1
    assert int(np.asarray(pack_bits_jnp(jnp.asarray(bits)))[0, 0]) == 2**31


def test_rejects_non_multiple():
    with pytest.raises(ValueError):
        pack_bits_jnp(jnp.zeros((2, 33), jnp.int32))
    with pytest.raises(ValueError):
        unpack_bits_jnp(jnp.zeros((2, 2), jnp.uint32), 33)
