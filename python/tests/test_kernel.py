"""Kernel-vs-ref sweeps — the CORE L1 correctness signal.

Hypothesis drives shapes and values through each Pallas kernel and asserts
bit-exact agreement with the pure-jnp oracles in ``compile.kernels.ref``.
Everything here is integer/bit arithmetic, so the comparison is
``array_equal``, not allclose.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.binary_conv import xnor_gemm
from compile.kernels.fp_conv import fp_gemm
from compile.kernels.maxpool import maxpool2x2
from compile.kernels.norm_binarize import norm_affine, norm_binarize
from compile.kernels.ref import (
    fp_gemm_ref,
    maxpool2x2_ref,
    norm_affine_ref,
    norm_binarize_ref,
    xnor_gemm_ref,
)
from compile.packing import pack_bits_jnp

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# xnor_gemm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 80),
    kw=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_xnor_gemm_matches_ref(m, n, kw, seed):
    rng = _rng(seed)
    k = kw * 32
    a = pack_bits_jnp(jnp.asarray(rng.integers(0, 2, (m, k))))
    w = pack_bits_jnp(jnp.asarray(rng.integers(0, 2, (n, k))))
    got = np.asarray(xnor_gemm(a, w, k))
    want = np.asarray(xnor_gemm_ref(a, w, k))
    assert np.array_equal(got, want)


@settings(**SETTINGS)
@given(
    kw=st.integers(1, 8),
    tail=st.integers(1, 31),
    seed=st.integers(0, 2**31 - 1),
)
def test_xnor_gemm_partial_last_word(kw, tail, seed):
    """k_bits not a multiple of 32: pad bits are zero in both operands and
    must not affect the match count."""
    rng = _rng(seed)
    k = (kw - 1) * 32 + tail
    m, n = 17, 13
    a_bits = np.zeros((m, kw * 32), np.int32)
    w_bits = np.zeros((n, kw * 32), np.int32)
    a_bits[:, :k] = rng.integers(0, 2, (m, k))
    w_bits[:, :k] = rng.integers(0, 2, (n, k))
    a = pack_bits_jnp(jnp.asarray(a_bits))
    w = pack_bits_jnp(jnp.asarray(w_bits))
    got = np.asarray(xnor_gemm(a, w, k))
    want = np.asarray(xnor_gemm_ref(a, w, k))
    assert np.array_equal(got, want)
    assert got.min() >= 0 and got.max() <= k


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 64), (64, 16)])
def test_xnor_gemm_block_shape_invariance(bm, bn):
    """Output must not depend on the BlockSpec tiling."""
    rng = _rng(7)
    m, n, k = 70, 33, 96
    a = pack_bits_jnp(jnp.asarray(rng.integers(0, 2, (m, k))))
    w = pack_bits_jnp(jnp.asarray(rng.integers(0, 2, (n, k))))
    base = np.asarray(xnor_gemm(a, w, k))
    got = np.asarray(xnor_gemm(a, w, k, bm=bm, bn=bn))
    assert np.array_equal(base, got)


def test_xnor_gemm_identity_rows():
    """a == w rows give the full match count k."""
    rng = _rng(3)
    k = 64
    bits = rng.integers(0, 2, (5, k))
    p = pack_bits_jnp(jnp.asarray(bits))
    out = np.asarray(xnor_gemm(p, p, k))
    assert np.array_equal(np.diag(out), np.full(5, k))


def test_xnor_gemm_complement_rows():
    """complemented rows give 0 matches."""
    rng = _rng(4)
    k = 96
    bits = rng.integers(0, 2, (4, k))
    a = pack_bits_jnp(jnp.asarray(bits))
    w = pack_bits_jnp(jnp.asarray(1 - bits))
    out = np.asarray(xnor_gemm(a, w, k))
    assert np.array_equal(np.diag(out), np.zeros(4, np.int32))


def test_xnor_gemm_rejects_bad_shapes():
    a = jnp.zeros((4, 3), jnp.uint32)
    w = jnp.zeros((4, 2), jnp.uint32)
    with pytest.raises(ValueError):
        xnor_gemm(a, w, 64)
    with pytest.raises(ValueError):
        xnor_gemm(a, jnp.zeros((4, 3), jnp.uint32), 0)
    with pytest.raises(ValueError):
        xnor_gemm(a, jnp.zeros((4, 3), jnp.uint32), 97)


# ---------------------------------------------------------------------------
# fp_gemm (first layer)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 64),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_fp_gemm_matches_ref(m, n, k, seed):
    rng = _rng(seed)
    a = jnp.asarray(rng.integers(-31, 32, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(-1, 2, (n, k)), jnp.int32)
    got = np.asarray(fp_gemm(a, w))
    want = np.asarray(fp_gemm_ref(a, w))
    assert np.array_equal(got, want)


def test_fp_gemm_6bit_range_no_overflow():
    """Worst-case layer-1 magnitude: 31 * 27 taps = 837 << int32 max."""
    a = jnp.full((4, 27), 31, jnp.int32)
    w = jnp.full((4, 27), 1, jnp.int32)
    out = np.asarray(fp_gemm(a, w))
    assert np.all(out == 31 * 27)


# ---------------------------------------------------------------------------
# norm_binarize / norm_affine
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_norm_binarize_matches_ref(m, n, seed):
    rng = _rng(seed)
    y = jnp.asarray(rng.integers(-1200, 1200, (m, n)), jnp.int32)
    c = jnp.asarray(rng.integers(-600, 600, (n,)), jnp.int32)
    got = np.asarray(norm_binarize(y, c))
    want = np.asarray(norm_binarize_ref(y, c))
    assert np.array_equal(got, want)


def test_norm_binarize_boundary_is_ge():
    """Paper eq. 8: y == c must produce 1 (>= not >)."""
    y = jnp.asarray([[5, -3]], jnp.int32)
    c = jnp.asarray([5, -3], jnp.int32)
    assert np.array_equal(np.asarray(norm_binarize(y, c)), [[1, 1]])


@settings(**SETTINGS)
@given(m=st.integers(1, 128), n=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_norm_affine_matches_ref(m, n, seed):
    rng = _rng(seed)
    y = jnp.asarray(rng.integers(-500, 500, (m, n)), jnp.int32)
    s = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = np.asarray(norm_affine(y, s, b))
    want = np.asarray(norm_affine_ref(y, s, b))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# maxpool2x2
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([2, 4, 8, 16]),
    c=st.sampled_from([1, 3, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(b, hw, c, seed):
    rng = _rng(seed)
    y = jnp.asarray(rng.integers(-1000, 1000, (b, hw, hw, c)), jnp.int32)
    got = np.asarray(maxpool2x2(y))
    want = np.asarray(maxpool2x2_ref(y))
    assert np.array_equal(got, want)


def test_maxpool_rejects_odd():
    with pytest.raises(ValueError):
        maxpool2x2(jnp.zeros((1, 3, 4, 2), jnp.int32))


def test_maxpool_commutes_with_binarize():
    """Monotone threshold => NB(MP(y)) == OR-pool(NB(y)) (paper §5.2: MP in
    pipeline with conv before NB)."""
    rng = _rng(11)
    y = jnp.asarray(rng.integers(-50, 50, (2, 8, 8, 16)), jnp.int32)
    c = jnp.asarray(rng.integers(-20, 20, (16,)), jnp.int32)
    pooled_then_nb = np.asarray(
        norm_binarize(np.asarray(maxpool2x2(y)).reshape(-1, 16), c)
    )
    nb = np.asarray(norm_binarize(np.asarray(y).reshape(-1, 16), c)).reshape(2, 8, 8, 16)
    nb_then_pool = np.asarray(maxpool2x2_ref(jnp.asarray(nb))).reshape(-1, 16)
    assert np.array_equal(pooled_then_nb, nb_then_pool)
