"""Training-loop smoke tests: loss decreases, folding preserves accuracy."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile.model import TINY, forward_packed
from compile.train import (
    evaluate_packed_path,
    evaluate_train_path,
    fold_params,
    records_to_jnp_params,
    train,
)


def test_train_tiny_learns_and_folds():
    params, metrics = train(
        TINY, steps=40, batch=32, n_train=256, n_test=128, lr=0.01, seed=0
    )
    # synthetic task, tiny net, 40 steps: should beat chance (10%) comfortably
    assert metrics["test_acc_train_path"] > 0.3
    recs = fold_params(params, TINY)
    jp = records_to_jnp_params(recs)
    _, _, x_te, y_te = data_mod.make_dataset(1, 128, hw=TINY.input_hw, seed=0)
    acc_hw = evaluate_packed_path(jp, x_te, y_te, TINY)
    # folded integer path must track the float path almost exactly
    assert abs(acc_hw - metrics["test_acc_train_path"]) < 0.03


def test_dataset_determinism():
    a = data_mod.make_dataset(32, 8, seed=42)
    b = data_mod.make_dataset(32, 8, seed=42)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    assert a[0].min() >= -31 and a[0].max() <= 31
    assert a[0].dtype == np.int32


def test_dataset_classes_distinguishable():
    """Templates of different classes differ in many pixels (the task is
    learnable)."""
    x, y, _, _ = data_mod.make_dataset(200, 1, seed=7)
    mean_by_class = [x[y == c].mean(axis=0) for c in range(10) if (y == c).any()]
    flat = np.stack([m.ravel() for m in mean_by_class])
    d = np.abs(flat[:, None, :] - flat[None, :, :]).mean(-1)
    off_diag = d[~np.eye(len(flat), dtype=bool)]
    assert off_diag.min() > 1.0
